//! Application workloads from the paper's introduction (§1): the reason
//! SpGEMM performance matters. Each app drives the OpSparse pipeline (or
//! a semiring variant) as its compute primitive:
//!
//! * [`amg`] — algebraic multigrid: the Galerkin triple product
//!   `A_coarse = R·A·P` is two SpGEMMs per level [1, 2].
//! * [`mcl`] — Markov clustering: the expansion step is `M²` [3].
//! * [`msbfs`] — multi-source BFS: frontier expansion is a boolean
//!   SpGEMM `F ⊗ A` [4].
//!
//! These apps are exactly the repeated-pattern workloads the device pool
//! and symbolic-reuse cache target: AMG re-setup on a fixed mesh reruns
//! the same Galerkin products every timestep, and MCL's expansion pattern
//! stabilizes as the clustering converges. [`SpgemmContext`] bundles a
//! [`DevicePool`] and a [`PatternCache`] so an app (or a caller looping
//! an app) reuses allocations and symbolic results across its multiplies.

pub mod amg;
pub mod mcl;
pub mod msbfs;

use crate::coordinator::cache::PatternCache;
use crate::coordinator::router::Router;
use crate::gpusim::{DevicePool, OverlapConfig, PoolStats};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use crate::spgemm::sharded::{multiply_sharded_with, ShardPlan, ShardReuse};
use anyhow::Result;
use std::sync::Arc;

/// Warm multiply state for an application: one device pool plus one
/// sparsity-pattern cache, threaded through every SpGEMM the app issues.
/// With a router attached ([`SpgemmContext::with_router`]) a multiply
/// whose working set exceeds the router's single-device budget runs
/// row-sharded across per-device pools instead — an app like AMG setup
/// then handles operators that only fit sharded without code changes.
pub struct SpgemmContext {
    pool: DevicePool,
    /// Per-device pools for the sharded path, grown on demand.
    shard_pools: Vec<DevicePool>,
    cache: PatternCache,
    router: Option<Router>,
    sharded_multiplies: u64,
    pub cfg: OpSparseConfig,
}

impl SpgemmContext {
    /// Default-capacity context (64 cached patterns).
    pub fn new() -> Self {
        SpgemmContext::with_capacity(64)
    }

    pub fn with_capacity(patterns: usize) -> Self {
        SpgemmContext {
            pool: DevicePool::new(),
            shard_pools: Vec::new(),
            cache: PatternCache::new(patterns),
            router: None,
            sharded_multiplies: 0,
            cfg: OpSparseConfig::default(),
        }
    }

    /// A context that consults `router` before every multiply and takes
    /// the row-sharded multi-device path when the router says the job
    /// exceeds one device's memory budget.
    pub fn with_router(router: Router) -> Self {
        let mut ctx = SpgemmContext::new();
        ctx.router = Some(router);
        ctx
    }

    /// `C = A·B` through the pooled pipeline, replaying the symbolic
    /// phase when this context has seen the pattern pair before. When a
    /// router is attached and the working set exceeds its device budget,
    /// the multiply runs row-sharded; the returned output's trace is then
    /// the serialized concatenation of the per-device traces (see
    /// [`crate::spgemm::ShardedOutput::into_output`]). The symbolic
    /// cache covers this path too, with **shard-aware keys**
    /// `(fingerprint(A[lo..hi]), fingerprint(B))`: repeated sharded
    /// traffic — AMG re-setup on an operator that only fits sharded —
    /// skips every per-shard symbolic phase on the second pass.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
        // shard_count, not route(): the context has no block engine, so
        // the router's tile-fill sampling would be wasted on every call
        if let Some(n_devices) = self.router.as_ref().and_then(|r| r.shard_count(a, b)) {
            self.sharded_multiplies += 1;
            let n = n_devices.max(1);
            while self.shard_pools.len() < n {
                self.shard_pools.push(DevicePool::new());
            }
            // the plan is a pure function of (A, B, n), so a re-setup on
            // the same operands recuts identical shard bounds and the
            // per-shard fingerprints key the same cache entries
            let plan = ShardPlan::balanced(&nprod_per_row(a, b), n);
            let b_fp = b.pattern_fingerprint();
            let keys: Vec<(u64, u64)> = (0..n)
                .map(|s| {
                    let (lo, hi) = plan.range(s);
                    (a.pattern_fingerprint_rows(lo, hi), b_fp)
                })
                .collect();
            let reuse = ShardReuse {
                entries: keys.iter().map(|&k| self.cache.lookup(k)).collect(),
            };
            let out = multiply_sharded_with(
                a,
                b,
                &self.cfg,
                &plan,
                Some(&mut self.shard_pools[..n]),
                OverlapConfig::default(),
                Some(&reuse),
            )?;
            for (s, key) in keys.into_iter().enumerate() {
                if reuse.entries[s].is_none() {
                    self.cache
                        .insert(key, Arc::new(SymbolicReuse::from_output(&out.shards[s])));
                }
            }
            return Ok(out.into_output());
        }
        let key = (a.pattern_fingerprint(), b.pattern_fingerprint());
        let reuse = self.cache.lookup(key);
        let out = multiply_reuse(a, b, &self.cfg, Some(&mut self.pool), reuse.as_deref())?;
        if reuse.is_none() {
            self.cache.insert(key, Arc::new(SymbolicReuse::from_output(&out)));
        }
        Ok(out)
    }

    /// Symbolic phases skipped so far. Unlike the coordinator's metrics
    /// (which split whole-job and shard-level counters), a context has
    /// one cache and one counter pair: a sharded multiply over `n`
    /// devices contributes `n` lookups here, one per shard.
    pub fn sym_cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Symbolic phases computed (and cached) so far (same granularity
    /// note as [`SpgemmContext::sym_cache_hits`]).
    pub fn sym_cache_misses(&self) -> u64 {
        self.cache.misses()
    }

    /// Multiplies that took the row-sharded multi-device path.
    pub fn sharded_multiplies(&self) -> u64 {
        self.sharded_multiplies
    }

    /// Cumulative device-pool counters (the single-device pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Cumulative counters of the per-device shard pools.
    pub fn shard_pool_stats(&self) -> Vec<PoolStats> {
        self.shard_pools.iter().map(|p| p.stats()).collect()
    }
}

impl Default for SpgemmContext {
    fn default() -> Self {
        SpgemmContext::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn context_power_iteration_reuses_everything() {
        let mut rng = Rng::new(41);
        let a = Uniform { n: 150, per_row: 7, jitter: 3 }.generate(&mut rng);
        let mut ctx = SpgemmContext::new();
        let gold = spgemm_reference(&a, &a);
        for i in 0..3 {
            let out = ctx.multiply(&a, &a).unwrap();
            assert!(out.c.approx_eq(&gold, 1e-12), "iteration {i}");
            assert_eq!(out.symbolic_skipped, i > 0);
        }
        assert_eq!(ctx.sym_cache_misses(), 1);
        assert_eq!(ctx.sym_cache_hits(), 2);
        assert!(ctx.pool_stats().pool_hits > 0);
    }

    #[test]
    fn sharded_context_is_bit_identical_and_recycles_shard_pools() {
        use crate::coordinator::router::RouterConfig;
        let mut rng = Rng::new(42);
        let a = Uniform { n: 260, per_row: 8, jitter: 4 }.generate(&mut rng);
        let mut plain = SpgemmContext::new();
        let gold = plain.multiply(&a, &a).unwrap();
        // memory-only routing: the point here is the sharded machinery,
        // not the cost model (which would decline so small a multiply)
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let mut ctx = SpgemmContext::with_router(router);
        let out = ctx.multiply(&a, &a).unwrap();
        assert_eq!(out.c, gold.c, "sharded context must not change the numerics");
        assert_eq!(ctx.sharded_multiplies(), 1);
        // the second identical multiply recycles every per-device pool
        // AND replays every shard's symbolic phase via the shard-aware
        // cache keys (the AMG re-setup property)
        let hits_before = ctx.sym_cache_hits();
        let out2 = ctx.multiply(&a, &a).unwrap();
        assert_eq!(out2.c, gold.c);
        assert_eq!(out2.trace.malloc_calls(), 0, "warm shard pools must be malloc-free");
        assert!(ctx.shard_pool_stats().iter().any(|s| s.pool_hits > 0));
        assert!(out2.symbolic_skipped, "every shard must replay its symbolic phase");
        assert!(
            ctx.sym_cache_hits() >= hits_before + 2,
            "per-shard entries must hit on the repeat"
        );
    }
}
