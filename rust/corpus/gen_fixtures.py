#!/usr/bin/env python3
"""Deterministic generator for the checked-in Matrix Market corpus.

The fixtures are hand-built stand-ins that mirror the *structure* of the
SuiteSparse matrices the OpSparse paper evaluates (Table 3): banded FEM
blocks, power-law webs, near-diagonal stencils, symmetric road graphs,
skew-symmetric circuit couplings. They are deliberately tiny (nnz <= ~1000,
max 12 nonzeros per row) so that the router's cheap working-set screen
`base + 12*nnz(A)*max_row_nnz(B) <= budget` proves "no shard" under the
corpus RouterConfig (256 KiB budget) and every route pin is deterministic.

Regenerating: `python3 gen_fixtures.py` from this directory rewrites every
fixture byte-identically (fixed LCG seed, no wall clock, no dict-order
dependence). The printed table is the provenance table in ARCHITECTURE.md.

Values are dyadic rationals (k/8) so text round-trips are exact in f64.
"""

import os

T = 16  # router tile width (RouterConfig::t)


class Lcg:
    """Tiny deterministic PRNG (MMIX constants) so regeneration is stable."""

    def __init__(self, seed):
        self.s = seed & 0xFFFFFFFFFFFFFFFF

    def next(self):
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        return self.s >> 33

    def below(self, n):
        return self.next() % n


def dyadic(rng, signed=True):
    v = (1 + rng.below(13)) / 8.0
    if signed and rng.below(2) == 1:
        v = -v
    return v


def fmt_real(v):
    # exact decimal for dyadic k/8 values: at most 3 fractional digits
    s = f"{v:.3f}".rstrip("0").rstrip(".")
    return s if s not in ("", "-0") else "0"


def distinct_tile_cols(rng, n, k, lo=0, hi=None, used_tiles=None):
    """Pick k columns in [lo, hi) whose 16-wide tiles are pairwise distinct."""
    hi = n if hi is None else hi
    used = set() if used_tiles is None else used_tiles
    avail = len({c // T for c in range(lo, hi)} - used)
    k = min(k, avail)
    cols = []
    while len(cols) < k:
        c = lo + rng.below(hi - lo)
        t = c // T
        if t in used:
            continue
        used.add(t)
        cols.append(c)
    return sorted(cols)


def write_mtx(path, field, symmetry, n, entries, comments=(), interleave=False):
    """entries: list of (row, col, value-or-None), 0-based; written 1-based."""
    lines = [f"%%MatrixMarket matrix coordinate {field} {symmetry}"]
    for c in comments:
        lines.append(f"% {c}")
    lines.append(f"{n} {n} {len(entries)}")
    for idx, (r, c, v) in enumerate(entries):
        if interleave and idx == len(entries) // 2:
            # the SuiteSparse archive interleaves comments and blank lines
            lines.append("")
            lines.append("% interleaved mid-body comment (reader must skip)")
        if field == "pattern":
            lines.append(f"{r + 1} {c + 1}")
        elif field == "integer":
            lines.append(f"{r + 1} {c + 1} {int(v)}")
        else:
            lines.append(f"{r + 1} {c + 1} {fmt_real(v)}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def expand(entries, symmetry, n):
    """Expanded (general-form) CSR row structure, mirroring the reader."""
    rows = [dict() for _ in range(n)]
    for r, c, v in entries:
        val = 1.0 if v is None else float(v)
        assert (r, c) not in rows[r], f"duplicate ({r},{c})"
        rows[r][c] = rows[r].get(c, 0.0) + val
        if symmetry == "symmetric" and r != c:
            rows[c][r] = rows[c].get(r, 0.0) + val
        elif symmetry == "skew-symmetric":
            assert r != c, "skew diagonal"
            rows[c][r] = rows[c].get(r, 0.0) - val
    return [sorted(d) for d in rows]


def fill_of(rows_cols):
    elems, tiles = 0, 0
    for cols in rows_cols:
        last = None
        for c in cols:
            t = c // T
            if t != last:
                tiles += 1
                last = t
            elems += 1
    return elems / (tiles * T) if tiles else 0.0


def stats(entries, symmetry, n):
    rc = expand(entries, symmetry, n)
    nnz = sum(len(c) for c in rc)
    maxr = max((len(c) for c in rc), default=0)
    fill = fill_of(rc)
    route = "Block" if fill >= 0.25 else "Hash"
    # corpus router shard screen: 256 KiB budget, upper bound must fit
    upper = 12 * nnz * maxr
    assert upper + 32 * nnz + 8 * (n + 1) < 256 * 1024, "fixture too big: would shard"
    assert not (0.20 <= fill < 0.30), f"fill {fill:.3f} too close to 0.25 threshold"
    return nnz, maxr, fill, route


def fem_cant_like(rng):
    # six dense 12x12 diagonal blocks, tile-aligned (FEM cantilever style)
    n, entries = 96, []
    for b in range(0, n, 16):
        for i in range(12):
            for j in range(i + 1):  # lower triangle incl. diagonal
                entries.append((b + i, b + j, dyadic(rng)))
    return "real", "symmetric", n, entries


def fem_ship_like(rng):
    # contiguous 12-wide tile-aligned runs marching down the band
    n, entries = 80, []
    for i in range(n):
        base = min((i // 16) * 16, n - 12)
        for j in range(12):
            entries.append((i, base + j, dyadic(rng)))
    return "real", "general", n, entries


def power_web_like(rng):
    # web graph: a few degree-12 hubs, long tail of degree 1..4
    n, entries = 200, []
    for i in range(n):
        deg = 12 if i < 8 else 1 + rng.below(4)
        used = set()
        cols = distinct_tile_cols(rng, n, deg - 1, used_tiles=used)
        # every page links toward a hub column (power-law in-degree)
        hub = rng.below(8)
        if hub // T not in used:
            cols.append(hub)
        for c in sorted(set(cols)):
            entries.append((i, c, None))
    return "pattern", "general", n, entries


def power_patents_like(rng):
    # citation counts: power-law out-degree, integer weights
    n, entries = 150, []
    for i in range(n):
        u = rng.below(1000) / 1000.0
        deg = 1 + int(7 * u * u)  # most rows 1-2, few rows up to 8
        for c in distinct_tile_cols(rng, n, deg):
            entries.append((i, c, 1 + rng.below(9)))
    return "integer", "general", n, entries


def tridiag_near_diag(rng):
    n, entries = 120, []
    for i in range(n):
        for c in (i - 1, i, i + 1):
            if 0 <= c < n:
                entries.append((i, c, dyadic(rng)))
    return "real", "general", n, entries


def stencil_lap2d_like(rng):
    # 5-point Laplacian on a 10x10 grid, lower triangle stored
    g, entries = 10, []
    n = g * g
    for i in range(n):
        for c in (i - g, i - 1, i):
            if c < 0:
                continue
            if c == i - 1 and i % g == 0:
                continue  # west neighbor wraps the grid row: not an edge
            entries.append((i, c, 4.0 if c == i else -1.0))
    return "real", "symmetric", n, entries


def skew_circuit_like(rng):
    # antisymmetric coupling matrix: strictly-lower scattered pairs
    n, entries = 64, []
    for i in range(2, n):
        for c in distinct_tile_cols(rng, n, 1 + rng.below(2), hi=i):
            entries.append((i, c, dyadic(rng, signed=False)))
    return "real", "skew-symmetric", n, entries


def pattern_road_like(rng):
    # road network: sparse symmetric graph, degree ~4, no self loops
    n, entries = 140, []
    for i in range(1, n):
        for c in distinct_tile_cols(rng, n, min(2, i), hi=i):
            entries.append((i, c, None))
    return "pattern", "symmetric", n, entries


def int_econ_like(rng):
    # input-output table: full diagonal plus scattered sector couplings
    n, entries = 110, []
    for i in range(n):
        used = {i // T}
        cols = distinct_tile_cols(rng, n, 5, used_tiles=used)
        for c in sorted(cols + [i]):
            entries.append((i, c, 1 + rng.below(9)))
    return "integer", "general", n, entries


def diag_dominant_jacobi(rng):
    n, entries = 130, []
    for i in range(n):
        used = {i // T}
        cols = distinct_tile_cols(rng, n, 2, used_tiles=used)
        for c in sorted(cols + [i]):
            entries.append((i, c, 8.0 if c == i else dyadic(rng)))
    return "real", "general", n, entries


def band_wide_cage_like(rng):
    # DNA electrophoresis style: scattered picks inside a wide band
    n, entries = 128, []
    for i in range(n):
        lo, hi = max(0, i - 16), min(n, i + 16)
        used = set()
        cols = distinct_tile_cols(rng, n, 2, lo=lo, hi=hi, used_tiles=used)
        for c in cols:
            entries.append((i, c, dyadic(rng)))
    return "real", "general", n, entries


def blocky_bsr_like(rng):
    # dense 12-wide runs at permuted tile-aligned block columns
    n, entries = 64, []
    for i in range(n):
        base = 16 * ((i // 16) * 3 % 4)
        for j in range(12):
            entries.append((i, base + j, dyadic(rng)))
    return "real", "general", n, entries


FIXTURES = [
    ("fem_cant_like", fem_cant_like, "FEM cantilever (cant): dense tile-aligned diagonal blocks"),
    ("fem_ship_like", fem_ship_like, "FEM ship section (ship_001): contiguous banded runs"),
    ("power_web_like", power_web_like, "web graph (webbase): power-law hubs, pattern-only"),
    ("power_patents_like", power_patents_like, "patent citations (patents_main): integer power-law"),
    ("tridiag_near_diag", tridiag_near_diag, "near-diagonal tridiagonal chain (1D Poisson)"),
    ("stencil_lap2d_like", stencil_lap2d_like, "5-point 2D Laplacian (10x10 grid), symmetric"),
    ("skew_circuit_like", skew_circuit_like, "circuit coupling (scircuit-ish), skew-symmetric"),
    ("pattern_road_like", pattern_road_like, "road network (roadNet): symmetric pattern graph"),
    ("int_econ_like", int_econ_like, "economic input-output (mac_econ): integer general"),
    ("diag_dominant_jacobi", diag_dominant_jacobi, "diagonally dominant Jacobi-ready system"),
    ("band_wide_cage_like", band_wide_cage_like, "wide-band scatter (cage-ish)"),
    ("blocky_bsr_like", blocky_bsr_like, "permuted dense block columns (BSR-friendly)"),
]


def main():
    out = os.path.dirname(os.path.abspath(__file__))
    print(f"{'fixture':24} {'field':8} {'symmetry':15} {'n':>4} {'nnz':>5} {'maxr':>4} {'fill':>6} route")
    for idx, (name, build, _desc) in enumerate(FIXTURES):
        rng = Lcg(0xC0DE0 + idx)
        field, symmetry, n, entries = build(rng)
        nnz, maxr, fill, route = stats(entries, symmetry, n)
        comments = [
            f"stand-in fixture mirroring the structure of: {_desc}",
            "generated by gen_fixtures.py (deterministic; see ARCHITECTURE.md)",
        ]
        write_mtx(
            os.path.join(out, f"{name}.mtx"), field, symmetry, n, entries,
            comments=comments, interleave=(idx % 3 == 0),
        )
        print(f"{name:24} {field:8} {symmetry:15} {n:>4} {nnz:>5} {maxr:>4} {fill:>6.3f} {route}")


if __name__ == "__main__":
    main()
