//! # OpSparse — Sparse General Matrix Multiplication framework
//!
//! Reproduction of *"OpSparse: A Highly Optimized Framework for Sparse
//! General Matrix Multiplication on GPUs"* (Du et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the complete row-wise two-phase SpGEMM pipeline
//!   with the paper's seven optimizations, three behavioral baselines
//!   (cuSPARSE/nsparse/spECK-like), a V100 cost-model simulator that
//!   replays device traces, synthetic generators for the 26-matrix suite,
//!   a PJRT runtime bridge, and the benchmark harness regenerating every
//!   table and figure of the paper's evaluation. On top of the per-call
//!   pipeline sits the serving layer: a grow-only device memory pool
//!   ([`gpusim::pool`]) and a sparsity-pattern symbolic-reuse cache
//!   ([`coordinator::cache`]) that make warm repeated-pattern traffic
//!   malloc-free and symbolic-free (see
//!   [`spgemm::pipeline::multiply_reuse`]), plus a row-sharded
//!   multi-device path ([`spgemm::sharded`], aggregated by
//!   [`gpusim::multi`]) for multiplies that exceed one device's memory,
//!   and a request-scoped tracing layer ([`obs`]) exporting Chrome
//!   trace-event JSON and Prometheus metrics
//!   ([`coordinator::Metrics::to_prometheus`]).
//!   See `docs/ARCHITECTURE.md` for the layer map and the paper-section →
//!   module table.
//! * **L2 (python/compile/model.py)** — the numeric-phase dense block
//!   accumulator as a JAX graph, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/block_matmul.py)** — the Pallas kernel
//!   behind L2 (TPU adaptation of the shared-memory hash accumulator; see
//!   DESIGN.md §Hardware-Adaptation).

pub mod apps;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod gen;
pub mod gpusim;
pub mod obs;
pub mod runtime;
pub mod sparse;
pub mod spgemm;
pub mod util;

/// Convenience alias used by substrate tests that need the gold SpGEMM
/// without importing the full pipeline machinery.
pub fn spgemm_reference_for_tests(a: &sparse::Csr, b: &sparse::Csr) -> sparse::Csr {
    spgemm::reference::spgemm_reference(a, b)
}
