//! Integration tests for the serving front door: coalescing (including
//! error fan-out when the leader dies), admission control, batching,
//! warm-start persistence, and the all-knobs-off parity with the raw
//! coordinator.
//!
//! Determinism pattern: the front door under test runs few workers with
//! `inflight_cap = 1`, and a **plug job** (a larger, different-pattern
//! multiply) is submitted first. The plug occupies the only inflight
//! slot, so the next request stays an outstanding leader while the test
//! thread submits the rest of its load — coalescing and queue-bound
//! decisions happen against a pinned-down front state, not a race.

use opsparse::coordinator::serve::{Serve, ServeConfig, ServeResult};
use opsparse::coordinator::{
    Coordinator, Job, NsPerProdFit, ReplanConfig, Router, RouterConfig,
};
use opsparse::gen::uniform::Uniform;
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn mat(n: usize, per_row: usize, seed: u64) -> Csr {
    Uniform { n, per_row, jitter: 2 }.generate(&mut Rng::new(seed))
}

/// A big different-pattern multiply that holds the single inflight slot
/// for milliseconds while the test thread submits microsecond-cheap
/// requests behind it.
fn plug() -> Csr {
    mat(1200, 10, 99)
}

fn base_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.inflight_cap = 1;
    // cheap deterministic seed instead of the startup suite calibration
    cfg.ns_per_prod = Some(1.0);
    cfg
}

#[test]
fn coalesced_requests_share_one_execution_bit_identically() {
    let (a, b) = (mat(250, 6, 1), mat(250, 6, 2));
    let expected = multiply(&a, &b, &OpSparseConfig::default()).unwrap().c;
    let n = 8;
    let serve = Serve::start(base_cfg()).unwrap();
    let p = plug();
    let plug_ticket = serve.submit("t", p.clone(), p);
    let tickets: Vec<_> = (0..n).map(|_| serve.submit("t", a.clone(), b.clone())).collect();
    assert!(plug_ticket.wait().csr().is_some());
    let mut shared: Option<Arc<Csr>> = None;
    let mut coalesced_waiters = 0;
    for t in tickets {
        match t.wait() {
            ServeResult::Done { c, coalesced, .. } => {
                assert_eq!(*c, expected, "every waiter sees the reference result");
                if coalesced {
                    coalesced_waiters += 1;
                }
                match &shared {
                    None => shared = Some(c),
                    Some(first) => assert!(
                        Arc::ptr_eq(first, &c),
                        "coalesced waiters must share ONE allocation — bit-identical by construction"
                    ),
                }
            }
            other => panic!("request did not complete: {other:?}"),
        }
    }
    assert_eq!(coalesced_waiters, n - 1, "everyone after the leader coalesced");
    let snap = serve.metrics_snapshot();
    assert_eq!(snap.coalesce_hits, (n - 1) as u64);
    assert_eq!(snap.jobs_completed, 2, "the plug and the one leader executed");
    assert_eq!(snap.sym_cache_misses, 2, "exactly one symbolic phase for the whole load");
    assert_eq!(snap.rejected_jobs, 0);
    assert!(snap.queue_depth_max >= 2, "leader + plug were outstanding together");
    assert!(snap.serve_p50_ns.is_some() && snap.serve_p99_ns.is_some());
    serve.shutdown();
}

/// A structurally poisoned `B` (same construction as
/// tests/failure_injection.rs): rows `0..sound` are a clean diagonal,
/// rows `sound..n` claim entries beyond `col`/`val` — shards touching
/// that region panic inside the worker's guard.
fn poisoned_b(n: usize, sound: usize) -> Csr {
    let mut rpt: Vec<usize> = (0..=sound).collect();
    for i in sound + 1..=n {
        rpt.push(sound + 2 * (i - sound));
    }
    let col: Vec<u32> = (0..sound as u32).collect();
    let val = vec![1.0f64; sound];
    Csr { rows: n, cols: n, rpt, col, val }
}

#[test]
fn leader_shard_panic_fans_out_one_error_per_waiter_and_workers_survive() {
    let mut cfg = base_cfg();
    cfg.workers = 2;
    // 4 KiB budget: these operands overflow it, so the router shards
    // them without ever slicing the poisoned rows itself
    cfg.device_memory_bytes = 4096;
    cfg.max_devices = 4;
    cfg.interconnect = None;
    let serve = Serve::start(cfg).unwrap();
    let p = plug();
    let plug_ticket = serve.submit("t", p.clone(), p);
    let a = Csr::identity(300); // row i of A references exactly row i of B
    let b = poisoned_b(300, 150);
    let n = 5;
    let tickets: Vec<_> = (0..n).map(|_| serve.submit("t", a.clone(), b.clone())).collect();
    assert!(plug_ticket.wait().csr().is_some());
    let mut shared: Option<Arc<String>> = None;
    for t in tickets {
        match t.wait() {
            ServeResult::Failed { error, .. } => match &shared {
                None => shared = Some(error),
                Some(first) => assert!(
                    Arc::ptr_eq(first, &error),
                    "the ONE error fans out to every waiter"
                ),
            },
            other => panic!("poisoned request must fail, got {other:?}"),
        }
    }
    let snap = serve.metrics_snapshot();
    assert_eq!(snap.jobs_failed, 1, "only the leader executed (and failed)");
    assert_eq!(snap.coalesce_hits, (n - 1) as u64);
    // the workers survive the poisoned shards: a healthy job completes
    let healthy = mat(260, 6, 3);
    let expected = multiply(&healthy, &healthy, &OpSparseConfig::default()).unwrap().c;
    match serve.submit("t", healthy.clone(), healthy).wait() {
        ServeResult::Done { c, .. } => assert_eq!(*c, expected),
        other => panic!("healthy follow-up failed: {other:?}"),
    }
    serve.shutdown();
}

#[test]
fn queue_full_rejects_immediately_under_a_one_slot_bound() {
    let mut cfg = base_cfg();
    cfg.coalesce = false; // the second request must be its own leader
    cfg.queue_cap = 1;
    let serve = Serve::start(cfg).unwrap();
    let p = plug();
    let plug_ticket = serve.submit("t", p.clone(), p.clone());
    let (a, b) = (mat(200, 5, 4), mat(200, 5, 5));
    // the plug holds the one queue slot: this must bounce synchronously
    let bounced = serve.submit("t", a.clone(), b.clone());
    match bounced.wait() {
        ServeResult::Rejected { queue_full } => assert!(queue_full),
        other => panic!("expected Rejected, got {other:?}"),
    }
    assert_eq!(serve.metrics_snapshot().rejected_jobs, 1);
    assert!(plug_ticket.wait().csr().is_some(), "the occupant is unaffected");
    // capacity freed: the same request is now admitted and served
    let expected = multiply(&a, &b, &OpSparseConfig::default()).unwrap().c;
    match serve.submit("t", a, b).wait() {
        ServeResult::Done { c, .. } => assert_eq!(*c, expected),
        other => panic!("post-drain request failed: {other:?}"),
    }
    let snap = serve.metrics_snapshot();
    assert_eq!(snap.rejected_jobs, 1, "no further rejections");
    assert_eq!(snap.jobs_failed, 0, "a rejection is not a failure");
    serve.shutdown();
}

#[test]
fn persistence_round_trip_restores_fit_and_routes_warm_patterns_identically() {
    let path = std::env::temp_dir()
        .join(format!("opsparse-serve-test-{}.state", std::process::id()));
    let path_s = path.to_string_lossy().into_owned();
    let _ = std::fs::remove_file(&path);
    let mk_cfg = || {
        let mut c = ServeConfig::default();
        c.workers = 2;
        c.ns_per_prod = Some(1.0);
        c.persist = Some(path_s.clone());
        c.device_memory_bytes = 4096; // warm pattern lives on the sharded route
        c.max_devices = 4;
        c.interconnect = None;
        c
    };
    let a = mat(300, 6, 21);
    let serve = Serve::start(mk_cfg()).unwrap();
    let mut route_before = None;
    let mut result_before: Option<Arc<Csr>> = None;
    for _ in 0..3 {
        match serve.submit("t", a.clone(), a.clone()).wait() {
            ServeResult::Done { c, route, .. } => {
                route_before = Some(route);
                result_before = Some(c);
            }
            other => panic!("warm-up job failed: {other:?}"),
        }
    }
    let warm = serve.metrics_snapshot();
    assert!(warm.replans >= 1, "repeat submissions re-planned from history");
    let fit_before = serve.fit().current().to_bits();
    serve.shutdown();
    assert!(path.exists(), "shutdown persisted the warm state");

    let serve2 = Serve::start(mk_cfg()).unwrap();
    assert_eq!(
        serve2.fit().current().to_bits(),
        fit_before,
        "the restored fit is bit-equal, not merely close"
    );
    match serve2.submit("t", a.clone(), a.clone()).wait() {
        ServeResult::Done { c, route, .. } => {
            assert_eq!(Some(route), route_before, "the warm pattern routes identically");
            assert_eq!(*c, **result_before.as_ref().unwrap(), "and computes identically");
        }
        other => panic!("post-restart job failed: {other:?}"),
    }
    let snap2 = serve2.metrics_snapshot();
    assert_eq!(
        snap2.replan_cold_misses, 0,
        "the first post-restart submit found warm history, not a cold miss"
    );
    assert_eq!(snap2.replans, 1, "…and was re-planned from it");
    serve2.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_persist_state_costs_only_the_warmth_never_a_panic() {
    // a truncated or garbage state file (crash mid-save, stale format,
    // disk corruption) must yield a clean cold start: Serve::start
    // succeeds, the first submit of the formerly-warm pattern is a cold
    // miss exactly as with no file at all, and nothing panics
    let path = std::env::temp_dir()
        .join(format!("opsparse-serve-corrupt-{}.state", std::process::id()));
    let path_s = path.to_string_lossy().into_owned();
    let _ = std::fs::remove_file(&path);
    let mk_cfg = || {
        let mut c = ServeConfig::default();
        c.workers = 2;
        c.ns_per_prod = Some(1.0);
        c.persist = Some(path_s.clone());
        c.device_memory_bytes = 4096; // warm pattern lives on the sharded route
        c.max_devices = 4;
        c.interconnect = None;
        c
    };
    let a = mat(300, 6, 21);
    let serve = Serve::start(mk_cfg()).unwrap();
    for _ in 0..2 {
        assert!(serve.submit("t", a.clone(), a.clone()).wait().csr().is_some());
    }
    serve.shutdown();
    let full = std::fs::read_to_string(&path).expect("shutdown persisted the warm state");

    // shape 1: truncation mid-save — the last line loses its final
    // field, which the loud parser must reject
    let cut = full.rfind(' ').unwrap();
    std::fs::write(&path, &full[..cut]).unwrap();
    let serve2 = Serve::start(mk_cfg()).expect("a truncated state file must not refuse to serve");
    assert!(serve2.submit("t", a.clone(), a.clone()).wait().csr().is_some());
    assert_eq!(
        serve2.metrics_snapshot().replan_cold_misses,
        1,
        "truncated state behaves exactly like no state file: the warm pattern plans cold"
    );
    serve2.shutdown();

    // shape 2: garbage bytes (wrong header, binary junk)
    std::fs::write(&path, b"\x00\x01\x7fnot a state file\xff\xfe").unwrap();
    let serve3 = Serve::start(mk_cfg()).expect("a garbage state file must not refuse to serve");
    assert!(serve3.submit("t", a.clone(), a.clone()).wait().csr().is_some());
    assert_eq!(
        serve3.metrics_snapshot().replan_cold_misses,
        1,
        "garbage state behaves exactly like no state file"
    );
    serve3.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn all_knobs_off_reproduces_the_raw_coordinator_exactly() {
    let mut cfg = base_cfg();
    cfg.coalesce = false;
    cfg.inflight_cap = usize::MAX;
    let serve = Serve::start(cfg).unwrap();
    let fit = Arc::new(NsPerProdFit::new(1.0));
    let raw_rc =
        RouterConfig { ns_per_prod: fit.current(), fit: Some(fit), ..RouterConfig::default() };
    let coord = Coordinator::start_with(1, Router::new(raw_rc), None, ReplanConfig::default());
    let m1 = mat(220, 6, 31);
    let m2 = mat(180, 9, 32);
    // two patterns, twice each (serially): the repeats exercise the
    // symbolic cache identically on both sides
    for (i, m) in [&m1, &m2, &m1, &m2].into_iter().enumerate() {
        let sres = serve.submit("t", m.clone(), m.clone()).wait();
        coord.submit(Job { id: i as u64, a: m.clone(), b: m.clone(), force_route: None });
        let cres = coord.recv().expect("raw coordinator result");
        match (sres, cres.c) {
            (ServeResult::Done { c, route, .. }, Ok(raw_c)) => {
                assert_eq!(*c, raw_c, "job {i}: bit-identical result");
                assert_eq!(route, cres.route, "job {i}: identical route");
            }
            (s, r) => panic!("job {i} diverged: serve={s:?} raw_ok={}", r.is_ok()),
        }
    }
    let s = serve.metrics_snapshot();
    let r = coord.metrics.snapshot();
    assert_eq!(
        (s.jobs_submitted, s.jobs_completed, s.jobs_failed),
        (r.jobs_submitted, r.jobs_completed, r.jobs_failed)
    );
    assert_eq!(
        (s.hash_routed, s.block_routed, s.sharded_routed),
        (r.hash_routed, r.block_routed, r.sharded_routed)
    );
    assert_eq!(
        (s.sym_cache_hits, s.sym_cache_misses, s.nprod_total),
        (r.sym_cache_hits, r.sym_cache_misses, r.nprod_total)
    );
    // the new machinery must stay silent with the knobs off
    assert_eq!(s.coalesce_hits, 0);
    assert_eq!(s.rejected_jobs, 0);
    assert_eq!(s.batches, 0);
    assert_eq!(s.batched_jobs, 0);
    // …including the failure-domain machinery (`--speculate off
    // --chaos off` is the default): no backups, no injected faults
    for snap in [&s, &r] {
        assert_eq!(snap.speculative_launches, 0);
        assert_eq!(snap.speculative_wins, 0);
        assert_eq!(snap.requeued_shards, 0);
        assert_eq!(snap.requeued_jobs, 0);
        assert_eq!(snap.worker_deaths, 0);
        assert_eq!(snap.chaos_delays, 0);
        assert_eq!(snap.chaos_pool_shrinks, 0);
    }
    serve.shutdown();
    coord.shutdown();
}

#[test]
fn batched_execution_is_bit_identical_and_flushes_on_both_watermarks() {
    // size watermark: exactly 3 distinct jobs, max_age far away
    let mut cfg = base_cfg();
    cfg.coalesce = false;
    cfg.inflight_cap = usize::MAX;
    cfg.batch.enabled = true;
    cfg.batch.max_jobs = 3;
    cfg.batch.max_age = Duration::from_secs(3600);
    let serve = Serve::start(cfg).unwrap();
    let mats: Vec<Csr> = (0..3).map(|i| mat(200 + 10 * i, 5, 40 + i as u64)).collect();
    let expected: Vec<Csr> =
        mats.iter().map(|m| multiply(m, m, &OpSparseConfig::default()).unwrap().c).collect();
    let tickets: Vec<_> =
        mats.iter().map(|m| serve.submit("t", m.clone(), m.clone())).collect();
    for (t, want) in tickets.into_iter().zip(&expected) {
        match t.wait() {
            ServeResult::Done { c, .. } => assert_eq!(*c, *want, "batched == singleton"),
            other => panic!("batched request failed: {other:?}"),
        }
    }
    let snap = serve.metrics_snapshot();
    assert_eq!(snap.batches, 1, "three members, one worker visit");
    assert_eq!(snap.batched_jobs, 3);
    serve.shutdown();

    // age watermark: a partial batch flushes on the dispatcher tick
    let mut cfg = base_cfg();
    cfg.coalesce = false;
    cfg.inflight_cap = usize::MAX;
    cfg.batch.enabled = true;
    cfg.batch.max_jobs = 100;
    cfg.batch.max_age = Duration::from_millis(0);
    let serve = Serve::start(cfg).unwrap();
    let m = mat(210, 5, 50);
    let want = multiply(&m, &m, &OpSparseConfig::default()).unwrap().c;
    for _ in 0..2 {
        match serve.submit("t", m.clone(), m.clone()).wait() {
            ServeResult::Done { c, .. } => assert_eq!(*c, want),
            other => panic!("aged-batch request failed: {other:?}"),
        }
    }
    let snap = serve.metrics_snapshot();
    assert!(snap.batches >= 1, "the age watermark flushed a partial batch");
    assert_eq!(snap.batched_jobs, 2);
    serve.shutdown();
}

#[test]
fn tenants_dequeue_round_robin_not_in_arrival_order() {
    let serve = Serve::start(base_cfg()).unwrap();
    let p = plug();
    let plug_ticket = serve.submit("a", p.clone(), p);
    // tenant a backlogs three more jobs while the plug holds the slot...
    let a_jobs: Vec<Csr> = (0..3).map(|i| mat(500, 8, 60 + i)).collect();
    let a_tickets: Vec<_> =
        a_jobs.iter().map(|m| serve.submit("a", m.clone(), m.clone())).collect();
    // ...then tenant b arrives with one job, behind four of tenant a's
    let b_mat = mat(240, 6, 70);
    let b_ticket = serve.submit("b", b_mat.clone(), b_mat);
    assert!(plug_ticket.wait().csr().is_some());
    // round-robin: a1 runs (a was next), then b's job — NOT a's backlog
    assert!(b_ticket.wait().csr().is_some());
    let [a1, a2, a3] = <[_; 3]>::try_from(a_tickets).ok().unwrap();
    assert!(
        a3.try_wait().is_none(),
        "tenant a's backlog must still be pending when tenant b is served"
    );
    for t in [a1, a2, a3] {
        assert!(t.wait().csr().is_some(), "the backlog still completes");
    }
    serve.shutdown();
}
