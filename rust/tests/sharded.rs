//! Row-sharded SpGEMM integration: sharding must change *where* rows are
//! computed and nothing else.
//!
//! Property across the generator families (uniform, power-law, stencil,
//! kron) and shard counts 1/2/4/8: the stitched sharded result is
//! bit-identical (`rpt`/`col`/`val`) to the unsharded pipeline, which
//! itself matches the sort-merge reference. Edge cases: empty matrices,
//! more shards than rows (empty shards), and one row per shard.

use opsparse::gen::kron::Kron;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::stencil::{Grid, Stencil};
use opsparse::gen::uniform::Uniform;
use opsparse::gpusim::{MultiDevice, V100};
use opsparse::sparse::stats::nprod_per_row;
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::spgemm::sharded::{multiply_sharded, ShardPlan};
use opsparse::util::rng::Rng;

/// One representative per generator family.
fn family_matrices() -> Vec<(&'static str, Csr)> {
    let mut rng = Rng::new(2077);
    vec![
        ("uniform", Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng)),
        (
            "powerlaw",
            PowerLaw {
                n: 500,
                alpha: 2.0,
                max_row: 60,
                mean_row: 4.0,
                hub_frac: 0.2,
                forced_giant_rows: 1,
            }
            .generate(&mut rng),
        ),
        (
            "stencil",
            Stencil { n: 400, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }
                .generate(&mut rng),
        ),
        ("kron", Kron { scale: 8, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }.generate(&mut rng)),
    ]
}

#[test]
fn sharded_is_bit_identical_across_families_and_shard_counts() {
    let cfg = OpSparseConfig::default();
    for (name, a) in family_matrices() {
        let gold = spgemm_reference(&a, &a);
        let unsharded = multiply(&a, &a, &cfg)
            .unwrap_or_else(|err| panic!("unsharded multiply failed on {name}: {err:#}"));
        assert!(
            unsharded.c.approx_eq(&gold, 1e-9),
            "{name}: pipeline vs reference: {:?}",
            unsharded.c.diff(&gold, 1e-9)
        );
        for shards in [1usize, 2, 4, 8] {
            let out = multiply_sharded(&a, &a, &cfg, shards)
                .unwrap_or_else(|err| panic!("{shards}-shard multiply failed on {name}: {err:#}"));
            assert_eq!(
                out.c, unsharded.c,
                "{name}: {shards}-shard result diverged from the unsharded pipeline"
            );
            assert!(
                out.c.approx_eq(&gold, 1e-9),
                "{name}: {shards}-shard vs reference: {:?}",
                out.c.diff(&gold, 1e-9)
            );
            assert_eq!(out.nprod, unsharded.nprod, "{name}: nprod must be preserved");
            assert_eq!(out.shards.len(), shards);
            out.c.validate().unwrap_or_else(|err| panic!("{name}: invalid CSR: {err:#}"));
        }
    }
}

#[test]
fn empty_matrix_shards_cleanly() {
    let cfg = OpSparseConfig::default();
    let z = Csr::zero(10, 10);
    for shards in [1usize, 4, 8] {
        let out = multiply_sharded(&z, &z, &cfg, shards).unwrap();
        assert_eq!(out.c.nnz(), 0);
        assert_eq!(out.c.rows, 10);
        out.c.validate().unwrap();
    }
}

#[test]
fn more_shards_than_rows_executes_empty_shards() {
    let cfg = OpSparseConfig::default();
    let mut rng = Rng::new(3001);
    let a = Uniform { n: 5, per_row: 3, jitter: 1 }.generate(&mut rng);
    let gold = multiply(&a, &a, &cfg).unwrap();
    let out = multiply_sharded(&a, &a, &cfg, 8).unwrap();
    assert_eq!(out.c, gold.c);
    assert_eq!(out.shards.len(), 8);
    let empty = out.shards.iter().filter(|s| s.c.rows == 0).count();
    assert!(empty >= 3, "5 rows over 8 shards leaves at least 3 empty shards, got {empty}");
    let rows_total: usize = out.shards.iter().map(|s| s.c.rows).sum();
    assert_eq!(rows_total, 5);
}

#[test]
fn one_row_per_shard() {
    let cfg = OpSparseConfig::default();
    let a = Csr::identity(16);
    let gold = multiply(&a, &a, &cfg).unwrap();
    let out = multiply_sharded(&a, &a, &cfg, 16).unwrap();
    assert_eq!(out.c, gold.c);
    for s in 0..16 {
        assert_eq!(out.plan.range(s), (s, s + 1));
        assert_eq!(out.shards[s].c.rows, 1);
    }
}

#[test]
fn plan_covers_rows_exactly_for_every_family() {
    for (name, a) in family_matrices() {
        let nprod = nprod_per_row(&a, &a);
        for shards in [1usize, 2, 4, 8] {
            let plan = ShardPlan::balanced(&nprod, shards);
            assert_eq!(plan.n_shards(), shards, "{name}");
            assert_eq!(plan.rows(), a.rows, "{name}");
            assert_eq!(plan.bounds()[0], 0, "{name}");
            for w in plan.bounds().windows(2) {
                assert!(w[0] <= w[1], "{name}: bounds must be non-decreasing");
            }
            let covered: usize = (0..shards).map(|s| plan.range(s).1 - plan.range(s).0).sum();
            assert_eq!(covered, a.rows, "{name}: shards must partition all rows");
        }
    }
}

#[test]
fn multi_device_makespan_shrinks_on_a_balanced_split() {
    // the per-family check of the bench acceptance: the 2-way split of a
    // decently sized multiply must beat one device, and the per-device
    // view must agree with the plan about balance
    let cfg = OpSparseConfig::default();
    let mut rng = Rng::new(3002);
    let a = PowerLaw {
        n: 3000,
        alpha: 2.2,
        max_row: 96,
        mean_row: 6.0,
        hub_frac: 0.15,
        forced_giant_rows: 0,
    }
    .generate(&mut rng);
    let one = multiply_sharded(&a, &a, &cfg, 1).unwrap();
    let four = multiply_sharded(&a, &a, &cfg, 4).unwrap();
    assert_eq!(one.c, four.c);
    let md1 = MultiDevice::simulate(one.traces(), &V100);
    let md4 = MultiDevice::simulate(four.traces(), &V100);
    assert!(
        md4.makespan_ns() < md1.makespan_ns(),
        "4 devices ({:.1}us) must beat 1 ({:.1}us)",
        md4.makespan_ns() / 1e3,
        md1.makespan_ns() / 1e3
    );
    assert!(md4.time_imbalance() < 1.25, "imbalance {:.3}", md4.time_imbalance());
    assert!(four.plan.load_imbalance() < 1.25, "plan imbalance {:.3}", four.plan.load_imbalance());
    let eff = md4.efficiency_vs(md1.makespan_ns());
    assert!(eff > 0.25, "4-way split should show real scaling, eff={eff:.2}");
}
