//! Service metrics: counters plus latency percentiles computed from a
//! bounded reservoir of observed job latencies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe metrics registry for the coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub hash_routed: AtomicU64,
    pub block_routed: AtomicU64,
    /// Total intermediate products processed (throughput numerator).
    pub nprod_total: AtomicU64,
    /// Latency samples in ns (bounded reservoir).
    latencies: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_latency(&self, ns: u64) {
        let mut l = self.latencies.lock().unwrap();
        if l.len() < 65_536 {
            l.push(ns);
        }
    }

    /// Latency percentile (0.0..=1.0) over the recorded samples.
    pub fn latency_percentile(&self, q: f64) -> Option<u64> {
        let mut l = self.latencies.lock().unwrap().clone();
        if l.is_empty() {
            return None;
        }
        l.sort_unstable();
        let idx = ((l.len() as f64 - 1.0) * q).round() as usize;
        Some(l[idx.min(l.len() - 1)])
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            hash_routed: self.hash_routed.load(Ordering::Relaxed),
            block_routed: self.block_routed.load(Ordering::Relaxed),
            nprod_total: self.nprod_total.load(Ordering::Relaxed),
            p50_ns: self.latency_percentile(0.50),
            p99_ns: self.latency_percentile(0.99),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub hash_routed: u64,
    pub block_routed: u64,
    pub nprod_total: u64,
    pub p50_ns: Option<u64>,
    pub p99_ns: Option<u64>,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "jobs: submitted={} completed={} failed={}", self.jobs_submitted, self.jobs_completed, self.jobs_failed)?;
        writeln!(f, "routes: hash={} block={}", self.hash_routed, self.block_routed)?;
        writeln!(f, "nprod total: {}", self.nprod_total)?;
        match (self.p50_ns, self.p99_ns) {
            (Some(p50), Some(p99)) => writeln!(
                f,
                "latency: p50={} p99={}",
                crate::util::fmt::ns(p50 as f64),
                crate::util::fmt::ns(p99 as f64)
            ),
            _ => writeln!(f, "latency: no samples"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        for ns in [100u64, 200, 300, 400, 1000] {
            m.observe_latency(ns);
        }
        let snap = m.snapshot();
        assert_eq!(snap.jobs_submitted, 3);
        assert_eq!(snap.p50_ns, Some(300));
        assert_eq!(snap.p99_ns, Some(1000));
    }

    #[test]
    fn empty_latency_is_none() {
        let m = Metrics::new();
        assert!(m.latency_percentile(0.5).is_none());
    }
}
