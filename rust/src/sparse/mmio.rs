//! MatrixMarket (`.mtx`) reader/writer — the SuiteSparse interchange format
//! the paper's suite ships in. Supports `matrix coordinate
//! real|integer|pattern general|symmetric|skew-symmetric`.
//!
//! The reader is strict in exactly the ways the corpus fuzz tests pin down
//! ([`MmioError`]): declared-vs-actual entry counts, 1-based index bounds,
//! duplicate coordinates, symmetric/skew storage convention (lower triangle
//! only, per the MatrixMarket spec), no diagonal in skew-symmetric files,
//! integral values in `integer` fields, finite values in `real` fields, and
//! a clear "unsupported" error for `complex` (instead of a generic bail).
//! Comment (`%`) and blank lines are skipped **anywhere** — the SuiteSparse
//! archive interleaves them mid-body.
//!
//! Every rejection is a typed [`MmioError`] carried inside the `anyhow`
//! error chain, so callers can `downcast_ref::<MmioError>()` to branch on
//! the failure mode while casual callers keep the plain `Result<Csr>` API.

use super::coo::Coo;
use super::csr::Csr;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// Value field of a coordinate MatrixMarket file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Field {
    Real,
    Integer,
    Pattern,
}

impl Field {
    pub const ALL: [Field; 3] = [Field::Real, Field::Integer, Field::Pattern];

    pub fn as_str(self) -> &'static str {
        match self {
            Field::Real => "real",
            Field::Integer => "integer",
            Field::Pattern => "pattern",
        }
    }
}

/// Symmetry of a coordinate MatrixMarket file. `Symmetric` and
/// `SkewSymmetric` files store the lower triangle only; the reader expands
/// them to general form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

impl Symmetry {
    pub const ALL: [Symmetry; 3] =
        [Symmetry::General, Symmetry::Symmetric, Symmetry::SkewSymmetric];

    pub fn as_str(self) -> &'static str {
        match self {
            Symmetry::General => "general",
            Symmetry::Symmetric => "symmetric",
            Symmetry::SkewSymmetric => "skew-symmetric",
        }
    }
}

/// Typed rejection reasons for malformed MatrixMarket input. Indices are
/// 1-based, matching the file text.
#[derive(Clone, Debug, PartialEq)]
pub enum MmioError {
    /// `complex` (or any other unknown) field — parseable format, value
    /// type we deliberately do not support.
    UnsupportedField(String),
    /// Body ended early or carried extra entries vs the size line.
    EntryCountMismatch { declared: usize, seen: usize },
    /// Entry coordinates outside the declared `rows x cols`.
    OutOfRange { row: usize, col: usize, rows: usize, cols: usize },
    /// The same coordinate appeared twice (MatrixMarket coordinate files
    /// list each nonzero once; summing duplicates silently would corrupt
    /// round-trips).
    Duplicate { row: usize, col: usize },
    /// A skew-symmetric file stored a diagonal entry (`a_ii = -a_ii` forces
    /// zero, so the format forbids them).
    SkewDiagonal { row: usize },
    /// A symmetric/skew-symmetric file stored a strictly-upper entry; the
    /// spec says lower triangle only.
    UpperTriangle { row: usize, col: usize },
    /// `real` value failed to parse or was non-finite (NaN/inf).
    BadReal { row: usize, col: usize },
    /// `integer` value was not an integer.
    BadInteger { row: usize, col: usize },
}

impl std::fmt::Display for MmioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmioError::UnsupportedField(field) => write!(
                f,
                "unsupported MatrixMarket field '{field}': only real|integer|pattern \
                 are supported (complex is recognized but unsupported)"
            ),
            MmioError::EntryCountMismatch { declared, seen } => {
                write!(f, "size line declared {declared} entries, body has {seen}")
            }
            MmioError::OutOfRange { row, col, rows, cols } => {
                write!(f, "entry ({row},{col}) out of bounds for {rows}x{cols}")
            }
            MmioError::Duplicate { row, col } => {
                write!(f, "duplicate entry at ({row},{col})")
            }
            MmioError::SkewDiagonal { row } => {
                write!(f, "skew-symmetric file stores diagonal entry at row {row}")
            }
            MmioError::UpperTriangle { row, col } => write!(
                f,
                "symmetric storage must be lower-triangular, found upper entry ({row},{col})"
            ),
            MmioError::BadReal { row, col } => {
                write!(f, "entry ({row},{col}): real value missing, unparseable, or non-finite")
            }
            MmioError::BadInteger { row, col } => {
                write!(f, "entry ({row},{col}): integer value missing or not integral")
            }
        }
    }
}

impl std::error::Error for MmioError {}

/// Parse MatrixMarket text into CSR (symmetric/skew storage expanded to
/// general form). Malformed input yields a typed [`MmioError`] in the
/// chain — never a panic.
pub fn read_matrix_market<R: Read>(reader: R) -> Result<Csr> {
    let mut lines = BufReader::new(reader).lines();
    let header = lines.next().context("empty MatrixMarket file")??;
    let head: Vec<String> = header.split_whitespace().map(|s| s.to_lowercase()).collect();
    ensure!(
        head.len() >= 5 && head[0] == "%%matrixmarket" && head[1] == "matrix",
        "bad MatrixMarket header: {header}"
    );
    ensure!(head[2] == "coordinate", "only coordinate format supported, got {}", head[2]);
    let field = match head[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        f => bail!(MmioError::UnsupportedField(f.to_string())),
    };
    let sym = match head[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        s => bail!("unsupported symmetry: {s}"),
    };

    // skip comments/blank lines, read size line
    let mut size_line = String::new();
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = t.to_string();
        break;
    }
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().context("bad size line"))
        .collect::<Result<_>>()?;
    ensure!(dims.len() == 3, "size line must have 3 fields, got: {size_line}");
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);
    ensure!(
        nnz <= rows.saturating_mul(cols),
        "declared nnz {nnz} exceeds {rows}x{cols}"
    );

    // never trust the header for pre-allocation (a hostile size line must
    // not OOM the process); grow organically past this cap
    let cap = nnz.min(1 << 22) * if sym == Symmetry::General { 1 } else { 2 };
    let mut coo = Coo::with_capacity(rows, cols, cap);
    let mut stored: HashSet<(usize, usize)> = HashSet::with_capacity(cap.min(1 << 22));
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        // comment and blank lines are legal anywhere in the body — the
        // SuiteSparse archive interleaves them between entries
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("missing row")?.parse()?;
        let c: usize = it.next().context("missing col")?.parse()?;
        if !(r >= 1 && r <= rows && c >= 1 && c <= cols) {
            bail!(MmioError::OutOfRange { row: r, col: c, rows, cols });
        }
        match sym {
            Symmetry::General => {}
            Symmetry::Symmetric if c > r => bail!(MmioError::UpperTriangle { row: r, col: c }),
            Symmetry::SkewSymmetric if r == c => bail!(MmioError::SkewDiagonal { row: r }),
            Symmetry::SkewSymmetric if c > r => {
                bail!(MmioError::UpperTriangle { row: r, col: c })
            }
            _ => {}
        }
        let v = match field {
            Field::Pattern => 1.0,
            Field::Real => {
                let v: f64 = it
                    .next()
                    .and_then(|tok| tok.parse().ok())
                    .ok_or(MmioError::BadReal { row: r, col: c })?;
                if !v.is_finite() {
                    bail!(MmioError::BadReal { row: r, col: c });
                }
                v
            }
            Field::Integer => {
                let v: i64 = it
                    .next()
                    .and_then(|tok| tok.parse().ok())
                    .ok_or(MmioError::BadInteger { row: r, col: c })?;
                v as f64
            }
        };
        if !stored.insert((r, c)) {
            bail!(MmioError::Duplicate { row: r, col: c });
        }
        seen += 1;
        if seen > nnz {
            bail!(MmioError::EntryCountMismatch { declared: nnz, seen });
        }
        coo.push(r - 1, c - 1, v);
        match sym {
            Symmetry::General => {}
            Symmetry::Symmetric if r != c => coo.push(c - 1, r - 1, v),
            Symmetry::SkewSymmetric => coo.push(c - 1, r - 1, -v),
            _ => {}
        }
    }
    if seen != nnz {
        bail!(MmioError::EntryCountMismatch { declared: nnz, seen });
    }
    coo.to_csr()
}

/// Read a `.mtx` file from disk.
pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_matrix_market(f)
}

/// Write CSR as `matrix coordinate real general` (the historical default).
pub fn write_matrix_market<W: Write>(m: &Csr, w: W) -> Result<()> {
    write_matrix_market_with(m, Field::Real, Symmetry::General, w)
}

/// Write CSR in an explicit `field x symmetry` representation.
///
/// The matrix must actually be representable in the requested form, and the
/// writer verifies rather than trusts:
/// * `Pattern` requires every stored value to be exactly `1.0` (what the
///   reader reconstructs), so `write -> read` round-trips bit-identically;
/// * `Integer` requires every value to be integral and within `i64`;
/// * `Symmetric` requires `a_ij == a_ji` for every stored entry and emits
///   the lower triangle;
/// * `SkewSymmetric` requires `a_ij == -a_ji` and an empty stored diagonal,
///   and emits the strictly-lower triangle.
pub fn write_matrix_market_with<W: Write>(
    m: &Csr,
    field: Field,
    sym: Symmetry,
    mut w: W,
) -> Result<()> {
    // validate representability first so a failed write never emits a
    // half-file some later reader chokes on
    let mut stored = 0usize;
    for i in 0..m.rows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let c = c as usize;
            match field {
                Field::Real => ensure!(v.is_finite(), "({},{}) non-finite value {v}", i + 1, c + 1),
                Field::Integer => ensure!(
                    v.fract() == 0.0 && v.abs() <= i64::MAX as f64,
                    "({},{}) value {v} not representable as integer",
                    i + 1,
                    c + 1
                ),
                Field::Pattern => ensure!(
                    v == 1.0,
                    "({},{}) value {v} not representable as pattern (must be 1.0)",
                    i + 1,
                    c + 1
                ),
            }
            match sym {
                Symmetry::General => stored += 1,
                Symmetry::Symmetric => {
                    ensure!(
                        m.get(c, i) == v,
                        "matrix not symmetric at ({},{})",
                        i + 1,
                        c + 1
                    );
                    if c <= i {
                        stored += 1;
                    }
                }
                Symmetry::SkewSymmetric => {
                    ensure!(c != i, "skew-symmetric cannot store diagonal ({},{})", i + 1, i + 1);
                    ensure!(
                        m.get(c, i) == -v,
                        "matrix not skew-symmetric at ({},{})",
                        i + 1,
                        c + 1
                    );
                    if c < i {
                        stored += 1;
                    }
                }
            }
        }
    }
    writeln!(w, "%%MatrixMarket matrix coordinate {} {}", field.as_str(), sym.as_str())?;
    writeln!(w, "% generated by opsparse")?;
    writeln!(w, "{} {} {}", m.rows, m.cols, stored)?;
    for i in 0..m.rows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let c = c as usize;
            let keep = match sym {
                Symmetry::General => true,
                Symmetry::Symmetric => c <= i,
                Symmetry::SkewSymmetric => c < i,
            };
            if !keep {
                continue;
            }
            match field {
                Field::Real => writeln!(w, "{} {} {:.17e}", i + 1, c + 1, v)?,
                Field::Integer => writeln!(w, "{} {} {}", i + 1, c + 1, v as i64)?,
                Field::Pattern => writeln!(w, "{} {}", i + 1, c + 1)?,
            }
        }
    }
    Ok(())
}

/// Write a `.mtx` file to disk (`real general` form).
pub fn write_file<P: AsRef<Path>>(m: &Csr, path: P) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    write_matrix_market(m, std::io::BufWriter::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mmio_err(r: Result<Csr>) -> MmioError {
        let err = r.expect_err("expected a parse rejection");
        err.downcast_ref::<MmioError>()
            .unwrap_or_else(|| panic!("not a typed MmioError: {err:#}"))
            .clone()
    }

    #[test]
    fn parse_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 3\n\
                    1 1 1.5\n\
                    2 3 -2.0\n\
                    3 1 4.0\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.rows, 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 0), 1.5);
        assert_eq!(m.get(1, 2), -2.0);
        assert_eq!(m.get(2, 0), 4.0);
    }

    #[test]
    fn parse_symmetric_expands() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 2\n\
                    1 1 1.0\n\
                    2 1 3.0\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn parse_skew_symmetric() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n\
                    2 2 1\n\
                    2 1 5.0\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.get(1, 0), 5.0);
        assert_eq!(m.get(0, 1), -5.0);
    }

    #[test]
    fn parse_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 2\n\
                    1 2\n\
                    2 1\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(1, 0), 1.0);
    }

    #[test]
    fn skips_comments_and_blank_lines_mid_body() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % leading comment\n\
                    \n\
                    3 3 3\n\
                    1 1 1.5\n\
                    \n\
                    % interleaved comment, as the SuiteSparse archive does\n\
                    2 3 -2.0\n\
                    \n\
                    3 1 4.0\n\
                    % trailing comment\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(1, 2), -2.0);
    }

    #[test]
    fn write_read_roundtrip() {
        let m = Csr::from_parts(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![0.5, -1.25, 3.75])
            .unwrap();
        let mut buf = Vec::new();
        write_matrix_market(&m, &mut buf).unwrap();
        let back = read_matrix_market(buf.as_slice()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn typed_writer_roundtrips_each_form() {
        // symmetric with off-diagonal pair and a diagonal entry
        let sym =
            Csr::from_parts(2, 2, vec![0, 2, 4], vec![0, 1, 0, 1], vec![2.0, 3.0, 3.0, -1.0])
                .unwrap();
        let mut buf = Vec::new();
        write_matrix_market_with(&sym, Field::Real, Symmetry::Symmetric, &mut buf).unwrap();
        assert_eq!(read_matrix_market(buf.as_slice()).unwrap(), sym);

        // skew-symmetric: empty diagonal, mirrored negation
        let skew = Csr::from_parts(2, 2, vec![0, 1, 2], vec![1, 0], vec![-7.0, 7.0]).unwrap();
        let mut buf = Vec::new();
        write_matrix_market_with(&skew, Field::Real, Symmetry::SkewSymmetric, &mut buf).unwrap();
        assert_eq!(read_matrix_market(buf.as_slice()).unwrap(), skew);

        // integer + pattern general
        let int = Csr::from_parts(2, 2, vec![0, 1, 2], vec![1, 0], vec![42.0, -3.0]).unwrap();
        let mut buf = Vec::new();
        write_matrix_market_with(&int, Field::Integer, Symmetry::General, &mut buf).unwrap();
        assert_eq!(read_matrix_market(buf.as_slice()).unwrap(), int);

        let pat = Csr::from_parts(2, 2, vec![0, 2, 3], vec![0, 1, 0], vec![1.0, 1.0, 1.0])
            .unwrap();
        let mut buf = Vec::new();
        write_matrix_market_with(&pat, Field::Pattern, Symmetry::General, &mut buf).unwrap();
        assert_eq!(read_matrix_market(buf.as_slice()).unwrap(), pat);
    }

    #[test]
    fn typed_writer_rejects_unrepresentable() {
        let m = Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.5, 2.0]).unwrap();
        // 1.5 is not an integer, not a pattern 1.0, and m is not symmetric
        assert!(write_matrix_market_with(&m, Field::Integer, Symmetry::General, Vec::new())
            .is_err());
        assert!(write_matrix_market_with(&m, Field::Pattern, Symmetry::General, Vec::new())
            .is_err());
        let asym = Csr::from_parts(2, 2, vec![0, 1, 1], vec![1], vec![4.0]).unwrap();
        assert!(write_matrix_market_with(&asym, Field::Real, Symmetry::Symmetric, Vec::new())
            .is_err());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_matrix_market(
            "%%MatrixMarket matrix array real general\n1 1 1\n".as_bytes()
        )
        .is_err());
        assert!(read_matrix_market("garbage\n".as_bytes()).is_err());
    }

    #[test]
    fn complex_field_gets_clear_unsupported_error() {
        let text = "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 2.0 3.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::UnsupportedField("complex".into()));
        assert!(e.to_string().contains("complex"), "{e}");
    }

    #[test]
    fn rejects_out_of_bounds_entry() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::OutOfRange { row: 3, col: 1, rows: 2, cols: 2 });
    }

    #[test]
    fn rejects_truncated_entries() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::EntryCountMismatch { declared: 2, seen: 1 });
    }

    #[test]
    fn rejects_extra_entries() {
        let text =
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n2 2 2.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::EntryCountMismatch { declared: 1, seen: 2 });
    }

    #[test]
    fn rejects_duplicate_entries() {
        let text =
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 1 2.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::Duplicate { row: 1, col: 1 });
    }

    #[test]
    fn rejects_skew_diagonal() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 2 1.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::SkewDiagonal { row: 2 });
    }

    #[test]
    fn rejects_upper_triangle_in_symmetric() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n2 2 1\n1 2 1.0\n";
        let e = mmio_err(read_matrix_market(text.as_bytes()));
        assert_eq!(e, MmioError::UpperTriangle { row: 1, col: 2 });
    }

    #[test]
    fn rejects_bad_values() {
        let nonfinite = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 inf\n";
        assert_eq!(
            mmio_err(read_matrix_market(nonfinite.as_bytes())),
            MmioError::BadReal { row: 1, col: 1 }
        );
        let fractional = "%%MatrixMarket matrix coordinate integer general\n1 1 1\n1 1 1.5\n";
        assert_eq!(
            mmio_err(read_matrix_market(fractional.as_bytes())),
            MmioError::BadInteger { row: 1, col: 1 }
        );
        let missing = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1\n";
        assert_eq!(
            mmio_err(read_matrix_market(missing.as_bytes())),
            MmioError::BadReal { row: 1, col: 1 }
        );
    }
}
