//! The OpSparse SpGEMM core: row-wise, two-phase (symbolic + numeric),
//! hash-accumulator SpGEMM with binning-based global load balance —
//! the paper's §5 with all seven optimizations, plus the switchable
//! inefficient variants used by the baselines and the ablation benches.

pub mod binning;
pub mod hash_table;
pub mod kernel_tables;
pub mod numeric;
pub mod one_phase;
pub mod pipeline;
pub mod reference;
pub mod request;
pub mod semiring;
pub mod sharded;
pub mod symbolic;

pub use kernel_tables::{BinningRanges, KernelConfig, NumericRanges, SymbolicRanges};
pub use pipeline::{
    multiply, multiply_batch, multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse,
};
pub use request::SpgemmRequest;
pub use sharded::{
    annotate_chunk_deps, multiply_sharded, multiply_sharded_pooled, multiply_sharded_with,
    MeasuredShard, ShardPlan, ShardReuse, ShardedOutput,
};

/// Which hash-probe implementation to use (paper §5.2 / Fig 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashVariant {
    /// OpSparse: one atomicCAS per probe iteration; the swapped value is
    /// kept in a register and reused.
    SingleAccess,
    /// nsparse/spECK: read the slot, then CAS, re-reading on contention —
    /// multiple shared-memory accesses per probe iteration.
    MultiAccess,
}

/// Which binning implementation to use (paper §5.1 / Figs 7–8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinningVariant {
    /// OpSparse: per-block shared-memory counters, one global atomic per
    /// (block, bin); max-row tracking enables the Algorithm-3 fast path.
    SharedMemory,
    /// nsparse: every row does an atomic directly on global memory.
    GlobalAtomic,
    /// spECK: global atomics plus an M x NUM_BIN metadata layout.
    GlobalWide,
}
