//! Sparse-matrix substrate: storage formats (CSR, COO, BSR, dense),
//! conversions, MatrixMarket IO, structural ops, and the statistics that
//! drive the paper's evaluation (nnz/row, n_prod, compression ratio).
//!
//! CSR is the interchange format of the whole framework, matching the paper
//! (§2.1.1): `rpt` (row pointers, len = rows+1), `col` (column indices,
//! sorted within each row), `val` (f64 values — the paper benchmarks in
//! double precision).

pub mod bsr;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod mmio;
pub mod ops;
pub mod stats;

pub use bsr::Bsr;
pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
