//! `cargo bench --bench pool_reuse` — the serving ablation: repeated
//! SpGEMM traffic on a warm worker (device memory pool + symbolic-reuse
//! cache) vs the paper's per-call allocation, plus a one-worker
//! coordinator run over repeated AMG/MCL-pattern jobs reporting its
//! pool/cache metrics.
//!
//! Env: `OPSPARSE_SCALE=tiny|small|medium` (default small),
//! `OPSPARSE_REPS=<n>` (default 5).

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    let reps = std::env::var("OPSPARSE_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    figures::pool_ablation(scale, reps).expect("pool_reuse ablation");
}
