//! Worker-pool SpGEMM service: jobs in, validated results out.
//!
//! A leader owns the job queues; hash jobs fan out to a worker pool, and
//! block jobs serialize through one dedicated PJRT thread. The PJRT
//! client is not `Send` (it wraps `Rc` + raw pointers), so the block
//! engine is **constructed inside** its thread from a factory closure and
//! never crosses threads — the same single-owner pattern a CUDA context
//! imposes.
//!
//! A [`Route::Sharded`] job is split at submit time into one **sub-job
//! per shard**. Sub-jobs ride the same queue as ordinary hash jobs, so
//! the shards of one oversized multiply interleave with many small jobs
//! across the whole worker pool, and a
//! [`ShardBarrier`](super::barrier::ShardBarrier) stitches the row
//! blocks back — bit-identical to the in-worker
//! [`crate::spgemm::sharded::multiply_sharded`] path — emitting exactly
//! one [`JobResult`] per parent job even when a shard fails.
//!
//! **Failure domains** (see `docs/ARCHITECTURE.md`): a worker that dies
//! at a sub-job boundary (chaos kill, standing in for a SIGKILL'd or
//! OOM'd process) requeues the message it owned onto the surviving
//! fleet and spawns its own replacement, so one death never fails a
//! parent job; a bounded retry budget ([`MAX_REQUEUES`]) converts
//! repeated deaths into one clean typed error. With `--speculate on`, a
//! monitor thread polls in-flight shard barriers and launches backup
//! sub-jobs for shards lagging the completed-shard median — first
//! result wins, bit-identically either way.

use super::barrier::{ShardBarrier, ShardFeedback, SpeculateConfig, SpeculationState};
use super::cache::PatternCache;
use super::chaos::{ChaosConfig, WorkerChaos};
use super::feedback::{Engine, ExecHistory, NsPerProdFit, ReplanConfig, RunObservation};
use super::metrics::Metrics;
use super::router::{EngineMode, Route, Router};
use crate::gpusim::{simulate, DevicePool, Trace, V100};
use crate::obs::{lane_worker, Span, Tracer, LANE_BLOCK, LANE_FRONT};
use crate::runtime::BlockEngine;
use crate::sparse::ops::row_slice;
use crate::sparse::stats::{nprod_per_row, total_nprod};
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use crate::spgemm::sharded::{MeasuredShard, ShardPlan};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Patterns each hash worker remembers. The repeated-pattern workloads
/// (AMG re-setup, MCL expansion, A·A iteration) cycle through a handful
/// of patterns, so 64 per worker is plenty. Note this bounds entry
/// *count* only — each entry's `row_nnz` is O(rows of A) (8 B/row), so
/// worst-case worker memory is 64 × 8 B × max-rows; revisit with a byte
/// budget if million-row patterns ever dominate traffic.
const WORKER_CACHE_PATTERNS: usize = 64;

/// How many times a sub-job may be requeued off dead workers before its
/// attempt chain is abandoned with a clean error (≤ `MAX_REQUEUES + 1`
/// delivery attempts total). Bounds livelock at `kill_prob = 1.0`.
const MAX_REQUEUES: u32 = 5;

/// Speculation-monitor poll cadence. 200µs is far below any makespan
/// worth speculating on (`SpeculateConfig::min_lag_ns`) and cheap: each
/// tick takes one registry lock and per-barrier state lock.
const SPECULATION_TICK: Duration = Duration::from_micros(200);

/// Batch size of the per-shard native block engines on the
/// [`Route::ShardedBlock`] path. The batch size only shapes the
/// simulated launch batching ([`crate::runtime::BlockEngine`]), never
/// the result, so the common native test size is fine fleet-wide.
const SHARD_BLOCK_P: usize = 16;

/// A multiply job. `force_route` overrides the router (tests/benches).
pub struct Job {
    pub id: u64,
    pub a: Csr,
    pub b: Csr,
    pub force_route: Option<Route>,
}

/// A completed job.
pub struct JobResult {
    pub id: u64,
    pub route: Route,
    pub c: Result<Csr>,
    /// End-to-end wall time from submit to result (queue wait included),
    /// on every route.
    pub wall_ns: u64,
    /// Total intermediate products (0 if the job failed early).
    pub nprod: usize,
}

/// One shard of a sharded job, schedulable on any hash worker. The
/// operands are shared (`Arc`), the row range is sliced inside the
/// worker, and the result reports to the parent's reassembly barrier.
struct ShardTask {
    barrier: Arc<ShardBarrier>,
    shard: usize,
    lo: usize,
    hi: usize,
    a: Arc<Csr>,
    b: Arc<Csr>,
    /// `B`'s pattern fingerprint, computed once at submit so every shard
    /// sub-job can key the shard-aware symbolic cache without re-hashing
    /// the shared operand.
    b_fp: u64,
    /// Simulate the shard's trace and report its device time to the
    /// barrier (set when adaptive re-planning records this parent). In a
    /// real deployment this is a pair of CUDA events around the shard's
    /// stream; here the simulator supplies the same measurement
    /// deterministically.
    measure: bool,
    /// Deliveries this task already survived being requeued from dead
    /// workers (bounded by [`MAX_REQUEUES`]).
    attempts: u32,
    /// A speculative backup launched by the monitor — its result reports
    /// through [`ShardBarrier::complete_from`] so a backup-first finish
    /// counts as a `speculative_win`.
    speculative: bool,
    /// Engine this shard runs on: hash shards take the worker's warm
    /// multiply path; [`Engine::Block`] shards run a per-task native
    /// (bit-exact) BSR engine over the same row slice
    /// ([`Route::ShardedBlock`] fan-out).
    engine: Engine,
    /// Block size `T` the shard plan's cuts are aligned to — the native
    /// engine of a block shard must be built with the same `T` or the
    /// slice's BSR conversion would pad different block contents than
    /// the unsharded conversion.
    block_t: usize,
}

enum WorkerMsg {
    /// A job, the route `submit` resolved for it, the submit-time
    /// instant — every route reports end-to-end (submit → result)
    /// latency, so queue wait is visible and the percentiles compare
    /// across routes — and the dead-worker requeue count.
    Run(Job, Route, Instant, u32),
    /// Several hash jobs delivered as **one worker visit**: the batched
    /// device pass the serving front door flushes
    /// ([`Coordinator::submit_batch`]). Every member runs the same code
    /// as a singleton [`WorkerMsg::Run`] against the same warm pool and
    /// pattern cache, so results are bit-identical to one-at-a-time
    /// submission — the batch only amortizes queue traffic and keeps
    /// the members' allocations on one pool. The trailing count is the
    /// dead-worker requeue budget spent so far (the batch requeues
    /// whole: its members were never started).
    RunBatch(Vec<Job>, Instant, u32),
    /// One shard of a sharded parent job.
    RunShard(ShardTask),
    Stop,
}

/// Factory that builds the block engine inside its worker thread.
pub type EngineFactory = Box<dyn FnOnce() -> Result<BlockEngine> + Send>;

pub(crate) fn finish(
    metrics: &Metrics,
    tx: &mpsc::Sender<JobResult>,
    id: u64,
    route: Route,
    c: Result<Csr>,
    nprod: usize,
    t0: Instant,
) {
    let wall_ns = t0.elapsed().as_nanos() as u64;
    if c.is_ok() {
        metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        metrics.nprod_total.fetch_add(nprod as u64, Ordering::Relaxed);
    } else {
        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }
    metrics.observe_latency(wall_ns);
    let _ = tx.send(JobResult { id, route, c, wall_ns, nprod });
}

/// Execute one hash-routed job against a worker's warm state (device
/// pool + pattern cache) and report it through `finish`. Shared by the
/// per-job [`WorkerMsg::Run`] arm and the batched [`WorkerMsg::RunBatch`]
/// arm — a batch is exactly this, looped, so batching changes *where*
/// the work runs (one worker visit), never *what* it computes.
#[allow(clippy::too_many_arguments)]
fn run_hash_job(
    job: Job,
    t0: Instant,
    pool: &mut DevicePool,
    cache: &mut PatternCache,
    cfg: &OpSparseConfig,
    fit: Option<&Arc<NsPerProdFit>>,
    engine_history: Option<&Arc<Mutex<ExecHistory>>>,
    metrics: &Metrics,
    tx_res: &mpsc::Sender<JobResult>,
    tracer: Option<&Arc<Tracer>>,
    lane: u64,
) {
    let id = job.id;
    let span_t0 = tracer.map(|t| t.now_ns());
    let pool_before = pool.stats();
    // the ENTIRE per-job body is one fault domain: a panic anywhere in
    // it (the multiply itself, the post-multiply refit/simulate, the
    // cache insert — e.g. a 2^-64 fingerprint collision making the
    // cached entry lie) must cost exactly this job. Anything narrower
    // would let a panic unwind through a RunBatch member loop and
    // strand the batch siblings without a JobResult — their waiters
    // would hang forever (tests/failure_injection.rs pins this).
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let key = (job.a.pattern_fingerprint(), job.b.pattern_fingerprint());
        let reuse = cache.lookup(key);
        if reuse.is_some() {
            metrics.sym_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            metrics.sym_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        match multiply_reuse(&job.a, &job.b, cfg, Some(pool), reuse.as_deref()) {
            Ok(out) => {
                let np = out.nprod;
                // online re-fit: fold this job's measured device time
                // into the live ns_per_prod fit. The fit is seeded from
                // (and the router compares it against) *simulated*
                // device ns, so the observation must be in the same
                // unit system — the simulator plays the CUDA-event role
                // here, exactly as on the RunShard path; host wall
                // clock would drift the fit with machine speed.
                // Cache-warm replays skip the symbolic phase and would
                // bias the full-pipeline constant low; skip them.
                if !out.symbolic_skipped {
                    let sim_ns = simulate(&out.trace, &V100).total_ns;
                    if let Some(f) = fit {
                        if f.observe(sim_ns, np as u64) {
                            metrics.refit_updates.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    // engine-tagged dispatch measurement (Auto mode
                    // only — `engine_history` is None otherwise): fold
                    // this job's simulated time into the pattern's hash
                    // EWMA so the dispatcher's next decision for this
                    // pattern compares measurements, not estimates.
                    if let Some(h) = engine_history {
                        let mut h = h.lock().unwrap_or_else(|e| e.into_inner());
                        h.record(
                            key,
                            RunObservation {
                                engine: Engine::Hash,
                                engine_ns: sim_ns,
                                nprod: np as u64,
                                ..Default::default()
                            },
                        );
                        metrics.history_patterns.store(h.len() as u64, Ordering::Relaxed);
                        metrics.history_evictions.store(h.evictions(), Ordering::Relaxed);
                    }
                }
                if reuse.is_none() {
                    cache.insert(key, Arc::new(SymbolicReuse::from_output(&out)));
                }
                // device-phase attribution for the exec span: replay the
                // op trace once more and keep the per-step durations.
                // Tracing-off skips this entirely (and allocates nothing)
                let phases = match tracer {
                    Some(_) => simulate(&out.trace, &V100).phase_spans(),
                    None => Vec::new(),
                };
                (Ok(out.c), np, phases)
            }
            Err(e) => (Err(e), 0, Vec::new()),
        }
    }));
    let (c, nprod, phases) = match outcome {
        Ok(r) => r,
        Err(_) => (
            Err(anyhow::anyhow!("job panicked (internal bug or corrupt reuse entry)")),
            0,
            Vec::new(),
        ),
    };
    metrics.observe_pool(&pool.stats().delta_since(&pool_before));
    // record-at-close, and *before* the result is sent: the request
    // root (closed by the fan-out this result triggers) must outlive
    // every child span's interval
    if let (Some(tr), Some(s0)) = (tracer, span_t0) {
        let s1 = tr.now_ns();
        let parent = tr.parent_for(id);
        let span_id = tr.next_span_id();
        tr.record(Span {
            trace: id,
            id: span_id,
            parent,
            name: "exec".to_string(),
            lane,
            t0_ns: s0,
            t1_ns: s1,
            args: vec![
                ("route".to_string(), "hash".to_string()),
                ("nprod".to_string(), nprod.to_string()),
            ],
            error: c.is_err(),
            instant: false,
        });
        tr.record_phases(id, span_id, lane, s0, s1, &phases);
    }
    finish(metrics, tx_res, id, Route::Hash, c, nprod, t0);
}

/// Execute one shard sub-job against a worker's warm state, reporting to
/// the parent's reassembly barrier. A chaos-injected straggler delay is
/// folded into the shard's measured timeline
/// ([`crate::gpusim::Timeline::inject_delay`]) so the barrier's timing
/// view — and therefore straggler speculation and the execution history
/// — sees the shard as slow, exactly as CUDA events would on hardware.
fn run_shard_task(
    task: ShardTask,
    injected_delay_ns: u64,
    pool: &mut DevicePool,
    cache: &mut PatternCache,
    cfg: &OpSparseConfig,
    metrics: &Metrics,
    worker_id: usize,
    tracer: Option<&Arc<Tracer>>,
) {
    // one shard of a sharded parent: slice the row range, run the full
    // pipeline, report to the reassembly barrier. The pattern cache IS
    // consulted, with shard-aware keys
    // `(fingerprint(A[lo..hi]), fingerprint(B))`, so repeated sharded
    // traffic (AMG re-setup) replays each shard's symbolic phase. A
    // panicking shard (poisoned rows reachable only from this shard's
    // slice) must cost the parent job, not this worker thread.
    metrics.observe_shard_subjob(worker_id);
    let span_t0 = tracer.map(|t| t.now_ns());
    if task.engine == Engine::Block {
        return run_block_shard_task(task, injected_delay_ns, tracer, worker_id, span_t0);
    }
    let pool_before = pool.stats();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let a_s = row_slice(&task.a, task.lo, task.hi)?;
        let key = (a_s.pattern_fingerprint(), task.b_fp);
        let reuse = cache.lookup(key);
        if reuse.is_some() {
            metrics.shard_sym_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            metrics.shard_sym_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        let out = multiply_reuse(&a_s, &task.b, cfg, Some(pool), reuse.as_deref())?;
        if reuse.is_none() {
            cache.insert(key, Arc::new(SymbolicReuse::from_output(&out)));
        }
        Ok(out)
    }));
    let r = match result {
        Ok(r) => r,
        Err(_) => Err(anyhow::anyhow!(
            "shard {} panicked (poisoned input or internal bug)",
            task.shard
        )),
    };
    metrics.observe_pool(&pool.stats().delta_since(&pool_before));
    // measured per-shard device time for the execution history: the
    // simulator plays the role CUDA events would on hardware. A
    // symbolic-cache-warm shard's trace has no symbolic ops, so its
    // time is incomparable with a cold shard's — report nothing and
    // let the barrier drop the mixed observation (only homogeneous
    // all-cold runs update the plan history, which also keeps the
    // measurement independent of which worker's cache a shard landed
    // on).
    let shard_ns = match (&r, task.measure) {
        (Ok(out), true) if !out.symbolic_skipped => {
            let mut tl = simulate(&out.trace, &V100);
            if injected_delay_ns > 0 {
                tl.inject_delay(injected_delay_ns as f64);
            }
            Some(tl.total_ns)
        }
        _ => None,
    };
    // shard attempt span, recorded before the barrier can resolve the
    // parent. A speculation loser lands after the request root closed:
    // `parent_for` then returns 0 and the span stands alone, tagged
    // `late` — never escaping a closed parent interval.
    if let (Some(tr), Some(s0)) = (tracer, span_t0) {
        let s1 = tr.now_ns();
        let trace = task.barrier.job_id();
        let parent = tr.parent_for(trace);
        let span_id = tr.next_span_id();
        let mut args = vec![
            ("shard".to_string(), task.shard.to_string()),
            ("rows".to_string(), format!("{}..{}", task.lo, task.hi)),
            ("attempt".to_string(), task.attempts.to_string()),
            ("speculative".to_string(), task.speculative.to_string()),
            ("worker".to_string(), worker_id.to_string()),
        ];
        if parent == 0 {
            args.push(("late".to_string(), "true".to_string()));
        }
        tr.record(Span {
            trace,
            id: span_id,
            parent,
            name: "shard".to_string(),
            lane: lane_worker(worker_id),
            t0_ns: s0,
            t1_ns: s1,
            args,
            error: r.is_err(),
            instant: false,
        });
        if let Ok(out) = &r {
            let phases = simulate(&out.trace, &V100).phase_spans();
            tr.record_phases(trace, span_id, lane_worker(worker_id), s0, s1, &phases);
        }
        metrics.phases.shard_exec.observe(s1.saturating_sub(s0));
    }
    task.barrier.complete_from(task.shard, r, shard_ns, task.speculative);
}

/// Execute one [`Route::ShardedBlock`] shard: a fresh native (bit-exact)
/// BSR engine over the row slice. The parent's cuts are aligned to
/// multiples of the engine block size
/// ([`ShardPlan::balanced_aligned`]), so each slice's BSR conversion
/// pads exactly the block rows the unsharded conversion would give it
/// and the stitched `C` is bit-identical to the unsharded block result.
/// No symbolic cache here: the BSR conversion *is* the symbolic phase,
/// and it is cheap next to the block-pair products. Measured time is the
/// engine's closed-form simulated ns (the same clock domain the
/// dispatcher's hash measurements use), plus any chaos-injected delay.
fn run_block_shard_task(
    task: ShardTask,
    injected_delay_ns: u64,
    tracer: Option<&Arc<Tracer>>,
    worker_id: usize,
    span_t0: Option<u64>,
) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let a_s = row_slice(&task.a, task.lo, task.hi)?;
        let mut engine = BlockEngine::native(SHARD_BLOCK_P, task.block_t.max(1))?;
        let c = engine.spgemm_csr(&a_s, &task.b)?;
        let nprod = total_nprod(&a_s, &task.b);
        Ok((c, nprod, engine.simulated_ns(&V100)))
    }));
    let r = match outcome {
        Ok(r) => r,
        Err(_) => Err(anyhow::anyhow!(
            "block shard {} panicked (poisoned input or internal bug)",
            task.shard
        )),
    };
    let (out, shard_ns) = match r {
        Ok((c, nprod, ns)) => (
            Ok(SpgemmOutput {
                c,
                trace: Trace::new(),
                nprod,
                sym_stats: Default::default(),
                num_stats: Default::default(),
                sym_fallback_rows: 0,
                symbolic_skipped: false,
            }),
            task.measure.then_some(ns + injected_delay_ns as f64),
        ),
        Err(e) => (Err(e), None),
    };
    // no op trace on the block path (the closed-form engine model is the
    // measurement), so the attempt span carries the simulated ns as an
    // arg instead of projected phase children
    if let (Some(tr), Some(s0)) = (tracer, span_t0) {
        let s1 = tr.now_ns();
        let trace = task.barrier.job_id();
        let parent = tr.parent_for(trace);
        let mut args = vec![
            ("shard".to_string(), task.shard.to_string()),
            ("rows".to_string(), format!("{}..{}", task.lo, task.hi)),
            ("attempt".to_string(), task.attempts.to_string()),
            ("speculative".to_string(), task.speculative.to_string()),
            ("engine".to_string(), "block".to_string()),
            ("worker".to_string(), worker_id.to_string()),
        ];
        if let Some(ns) = shard_ns {
            args.push(("sim_ns".to_string(), format!("{ns:.0}")));
        }
        if parent == 0 {
            args.push(("late".to_string(), "true".to_string()));
        }
        tr.record(Span {
            trace,
            id: tr.next_span_id(),
            parent,
            name: "shard".to_string(),
            lane: lane_worker(worker_id),
            t0_ns: s0,
            t1_ns: s1,
            args,
            error: out.is_err(),
            instant: false,
        });
    }
    task.barrier.complete_from(task.shard, out, shard_ns, task.speculative);
}

/// Everything a hash worker (or its respawned replacement) needs,
/// bundled so the death path can hand it to the next generation.
#[derive(Clone)]
struct WorkerShared {
    rx: Arc<Mutex<mpsc::Receiver<WorkerMsg>>>,
    /// A clone of the hash sender: dead workers requeue their in-flight
    /// message through it onto the surviving fleet.
    tx_requeue: mpsc::Sender<WorkerMsg>,
    tx_res: mpsc::Sender<JobResult>,
    metrics: Arc<Metrics>,
    fit: Option<Arc<NsPerProdFit>>,
    /// Engine-tagged execution history the workers record measured
    /// per-engine timings into — `Some` only under [`EngineMode::Auto`]
    /// (with replanning on), so every other mode's history contents and
    /// gauges are bit-identical to the pre-dispatch coordinator.
    engine_history: Option<Arc<Mutex<ExecHistory>>>,
    chaos: ChaosConfig,
    /// Replacement-worker handles, pushed by each dying worker *before*
    /// it exits so [`Coordinator::shutdown`]'s drain loop can't miss
    /// one.
    replacements: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Request tracer — `None` unless tracing is on, so the default
    /// serve hot path performs zero tracing work.
    tracer: Option<Arc<Tracer>>,
}

/// The trace a worker message belongs to: the job id (batches trace as
/// their first member — the whole visit rides one lane anyway).
fn msg_trace(msg: &WorkerMsg) -> u64 {
    match msg {
        WorkerMsg::RunShard(task) => task.barrier.job_id(),
        WorkerMsg::Run(job, _, _, _) => job.id,
        WorkerMsg::RunBatch(jobs, _, _) => jobs.first().map(|j| j.id).unwrap_or(0),
        WorkerMsg::Stop => 0,
    }
}

fn spawn_hash_worker(sh: WorkerShared, worker_id: usize, generation: u64) -> JoinHandle<()> {
    std::thread::spawn(move || hash_worker_loop(sh, worker_id, generation))
}

/// A worker died at a sub-job boundary (chaos kill — the stand-in for a
/// SIGKILL'd or OOM'd worker process). It still owns the message it
/// dequeued, so: requeue it onto the surviving fleet (or abandon the
/// attempt chain with a clean error once the retry budget is spent),
/// then spawn a replacement so the fleet keeps its width — shutdown's
/// stop-marker count stays correct and capacity never decays.
fn worker_died(sh: &WorkerShared, worker_id: usize, generation: u64, msg: WorkerMsg) {
    sh.metrics.worker_deaths.fetch_add(1, Ordering::Relaxed);
    let lane = lane_worker(worker_id);
    match msg {
        WorkerMsg::RunShard(mut task) => {
            let trace = task.barrier.job_id();
            if task.attempts >= MAX_REQUEUES {
                let (shard, attempts) = (task.shard, task.attempts);
                if let Some(tr) = sh.tracer.as_ref() {
                    let t = tr.now_ns();
                    let parent = tr.parent_for(trace);
                    tr.record(Span {
                        trace,
                        id: tr.next_span_id(),
                        parent,
                        name: "shard_abandoned".to_string(),
                        lane,
                        t0_ns: t,
                        t1_ns: t,
                        args: vec![
                            ("shard".to_string(), shard.to_string()),
                            ("attempt".to_string(), attempts.to_string()),
                            ("worker".to_string(), worker_id.to_string()),
                        ],
                        error: true,
                        instant: false,
                    });
                }
                task.barrier.abandon(
                    shard,
                    anyhow::anyhow!(
                        "shard {shard} retry budget exhausted \
                         ({attempts} requeues after worker deaths)"
                    ),
                );
            } else {
                task.attempts += 1;
                sh.metrics.requeued_shards.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = sh.tracer.as_ref() {
                    let parent = tr.parent_for(trace);
                    tr.instant(
                        trace,
                        parent,
                        lane,
                        "shard_requeue",
                        vec![
                            ("shard".to_string(), task.shard.to_string()),
                            ("attempt".to_string(), task.attempts.to_string()),
                            ("worker".to_string(), worker_id.to_string()),
                        ],
                    );
                }
                let _ = sh.tx_requeue.send(WorkerMsg::RunShard(task));
            }
        }
        WorkerMsg::Run(job, route, t0, attempts) => {
            if attempts >= MAX_REQUEUES {
                if let Some(tr) = sh.tracer.as_ref() {
                    let t = tr.now_ns();
                    let parent = tr.parent_for(job.id);
                    tr.record(Span {
                        trace: job.id,
                        id: tr.next_span_id(),
                        parent,
                        name: "job_abandoned".to_string(),
                        lane,
                        t0_ns: t,
                        t1_ns: t,
                        args: vec![
                            ("attempt".to_string(), attempts.to_string()),
                            ("worker".to_string(), worker_id.to_string()),
                        ],
                        error: true,
                        instant: false,
                    });
                }
                finish(
                    &sh.metrics,
                    &sh.tx_res,
                    job.id,
                    route,
                    Err(anyhow::anyhow!(
                        "job retry budget exhausted ({attempts} requeues after worker deaths)"
                    )),
                    0,
                    t0,
                );
            } else {
                sh.metrics.requeued_jobs.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = sh.tracer.as_ref() {
                    let parent = tr.parent_for(job.id);
                    tr.instant(
                        job.id,
                        parent,
                        lane,
                        "job_requeue",
                        vec![
                            ("attempt".to_string(), (attempts + 1).to_string()),
                            ("worker".to_string(), worker_id.to_string()),
                        ],
                    );
                }
                let _ = sh.tx_requeue.send(WorkerMsg::Run(job, route, t0, attempts + 1));
            }
        }
        WorkerMsg::RunBatch(jobs, t0, attempts) => {
            // the batch requeues whole: the kill fired before any member
            // started, so no member ran twice
            if attempts >= MAX_REQUEUES {
                for job in jobs {
                    if let Some(tr) = sh.tracer.as_ref() {
                        let t = tr.now_ns();
                        let parent = tr.parent_for(job.id);
                        tr.record(Span {
                            trace: job.id,
                            id: tr.next_span_id(),
                            parent,
                            name: "job_abandoned".to_string(),
                            lane,
                            t0_ns: t,
                            t1_ns: t,
                            args: vec![
                                ("attempt".to_string(), attempts.to_string()),
                                ("worker".to_string(), worker_id.to_string()),
                            ],
                            error: true,
                            instant: false,
                        });
                    }
                    finish(
                        &sh.metrics,
                        &sh.tx_res,
                        job.id,
                        Route::Hash,
                        Err(anyhow::anyhow!(
                            "batch retry budget exhausted \
                             ({attempts} requeues after worker deaths)"
                        )),
                        0,
                        t0,
                    );
                }
            } else {
                sh.metrics.requeued_jobs.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = sh.tracer.as_ref() {
                    let trace = jobs.first().map(|j| j.id).unwrap_or(0);
                    let parent = tr.parent_for(trace);
                    tr.instant(
                        trace,
                        parent,
                        lane,
                        "batch_requeue",
                        vec![
                            ("members".to_string(), jobs.len().to_string()),
                            ("attempt".to_string(), (attempts + 1).to_string()),
                            ("worker".to_string(), worker_id.to_string()),
                        ],
                    );
                }
                let _ = sh.tx_requeue.send(WorkerMsg::RunBatch(jobs, t0, attempts + 1));
            }
        }
        WorkerMsg::Stop => {
            // not reachable (Stop is handled before chaos), but if it
            // ever were, the marker must survive for the shutdown count
            let _ = sh.tx_requeue.send(WorkerMsg::Stop);
        }
    }
    let replacement = spawn_hash_worker(sh.clone(), worker_id, generation + 1);
    sh.replacements.lock().unwrap_or_else(|e| e.into_inner()).push(replacement);
}

/// The hash-worker loop: warm per-worker state (a grow-only device pool
/// and a symbolic-reuse cache, both single-owner — no locks), messages
/// off the shared queue, chaos consulted at every sub-job boundary.
fn hash_worker_loop(sh: WorkerShared, worker_id: usize, generation: u64) {
    let mut pool = DevicePool::new();
    let mut cache = PatternCache::new(WORKER_CACHE_PATTERNS);
    let cfg = OpSparseConfig::default();
    let mut chaos = WorkerChaos::new(&sh.chaos, worker_id, generation);
    loop {
        let msg = {
            let guard = sh.rx.lock().unwrap();
            guard.recv()
        };
        let msg = match msg {
            Ok(WorkerMsg::Stop) | Err(_) => return,
            Ok(m) => m,
        };
        // chaos fires at the sub-job boundary, while this worker still
        // owns the dequeued message: a kill hands it to worker_died for
        // requeueing, so injection never loses work — and never tears a
        // result, because the sub-job either runs the normal path to
        // completion or never starts here.
        let mut injected_delay_ns = 0u64;
        if !sh.chaos.is_off() {
            let fault = chaos.at_boundary();
            // chaos args carried on every injection instant so a trace
            // alone is enough to replay the schedule (satellite: chaos
            // observability)
            let chaos_args = || {
                vec![
                    ("seed".to_string(), sh.chaos.seed.to_string()),
                    ("worker".to_string(), worker_id.to_string()),
                    ("generation".to_string(), generation.to_string()),
                ]
            };
            if fault.delay_ns > 0 {
                sh.metrics.chaos_delays.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = sh.tracer.as_ref() {
                    let trace = msg_trace(&msg);
                    let mut args = chaos_args();
                    args.push(("delay_ns".to_string(), fault.delay_ns.to_string()));
                    tr.instant(trace, tr.parent_for(trace), lane_worker(worker_id), "chaos_delay", args);
                }
                std::thread::sleep(Duration::from_nanos(fault.delay_ns));
                injected_delay_ns = fault.delay_ns;
            }
            if fault.shrink_pool {
                sh.metrics.chaos_pool_shrinks.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = sh.tracer.as_ref() {
                    let trace = msg_trace(&msg);
                    tr.instant(
                        trace,
                        tr.parent_for(trace),
                        lane_worker(worker_id),
                        "chaos_pool_shrink",
                        chaos_args(),
                    );
                }
                pool = DevicePool::new();
                cache = PatternCache::new(WORKER_CACHE_PATTERNS);
            }
            if fault.kill {
                if let Some(tr) = sh.tracer.as_ref() {
                    let trace = msg_trace(&msg);
                    tr.instant(
                        trace,
                        tr.parent_for(trace),
                        lane_worker(worker_id),
                        "chaos_kill",
                        chaos_args(),
                    );
                }
                worker_died(&sh, worker_id, generation, msg);
                return;
            }
        }
        match msg {
            WorkerMsg::RunShard(task) => {
                run_shard_task(
                    task,
                    injected_delay_ns,
                    &mut pool,
                    &mut cache,
                    &cfg,
                    &sh.metrics,
                    worker_id,
                    sh.tracer.as_ref(),
                );
            }
            WorkerMsg::Run(job, _, t0, _) => {
                run_hash_job(
                    job,
                    t0,
                    &mut pool,
                    &mut cache,
                    &cfg,
                    sh.fit.as_ref(),
                    sh.engine_history.as_ref(),
                    &sh.metrics,
                    &sh.tx_res,
                    sh.tracer.as_ref(),
                    lane_worker(worker_id),
                );
            }
            WorkerMsg::RunBatch(jobs, t0, _) => {
                // one worker visit, many members: each runs the
                // identical singleton path against this worker's pool
                // and cache, so a batch's results match one-at-a-time
                // submission bit for bit while repeated patterns warm
                // the same cache within the visit
                for job in jobs {
                    run_hash_job(
                        job,
                        t0,
                        &mut pool,
                        &mut cache,
                        &cfg,
                        sh.fit.as_ref(),
                        sh.engine_history.as_ref(),
                        &sh.metrics,
                        &sh.tx_res,
                        sh.tracer.as_ref(),
                        lane_worker(worker_id),
                    );
                }
            }
            WorkerMsg::Stop => return,
        }
    }
}

/// The coordinator: spawn, submit, drain, join.
pub struct Coordinator {
    tx_hash: mpsc::Sender<WorkerMsg>,
    tx_block: Option<mpsc::Sender<WorkerMsg>>,
    rx_results: mpsc::Receiver<JobResult>,
    tx_results: mpsc::Sender<JobResult>,
    workers: Vec<JoinHandle<()>>,
    /// Replacements spawned by dying workers (chaos kills), joined by
    /// `shutdown`'s drain loop after the original handles.
    replacements: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Straggler-speculation monitor (spawned only with `--speculate
    /// on`) and its stop flag.
    monitor: Option<JoinHandle<()>>,
    monitor_stop: Arc<AtomicBool>,
    speculate: SpeculateConfig,
    /// In-flight shard barriers the monitor watches. `Weak` — the
    /// shard tasks own the barrier; a completed parent's entry prunes
    /// itself on the next tick.
    spec_registry: Arc<Mutex<Vec<Weak<ShardBarrier>>>>,
    router: Router,
    /// Adaptive re-planning knobs (see [`ReplanConfig`]).
    replan: ReplanConfig,
    /// Pattern-keyed execution history: written by shard barriers on
    /// parent completion, read at submit time to re-cut warm patterns.
    history: Arc<Mutex<ExecHistory>>,
    /// Whether the no-block-engine downgrade has been logged (once per
    /// coordinator — the `block_fallbacks` metric counts every event).
    block_fallback_logged: AtomicBool,
    /// Request tracer — `None` unless the serving layer turned tracing
    /// on ([`Coordinator::start_traced`]).
    tracer: Option<Arc<Tracer>>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start `n_workers` hash workers plus (optionally) one block worker
    /// built from `engine_factory`, with the default adaptive
    /// re-planning config (enabled; see [`Coordinator::start_with`]).
    pub fn start(n_workers: usize, router: Router, engine_factory: Option<EngineFactory>) -> Self {
        Coordinator::start_with(n_workers, router, engine_factory, ReplanConfig::default())
    }

    /// [`Coordinator::start`] with explicit [`ReplanConfig`]:
    /// `replan.enabled == false` is the ablation baseline — no history
    /// is recorded, every sharded job is proxy-planned, and the job path
    /// does exactly what it did before the feedback layer existed.
    ///
    /// When the router carries a live fit
    /// ([`super::RouterConfig::with_live_fit`]), hash workers fold each
    /// completed job's measured execution time back into it
    /// (`refit_updates` in the metrics), so the shard-vs-stay decision
    /// tracks measured traffic.
    pub fn start_with(
        n_workers: usize,
        router: Router,
        engine_factory: Option<EngineFactory>,
        replan: ReplanConfig,
    ) -> Self {
        Coordinator::start_full(
            n_workers,
            router,
            engine_factory,
            replan,
            SpeculateConfig::default(),
            ChaosConfig::off(),
        )
    }

    /// [`Coordinator::start_with`] plus the failure-domain knobs:
    /// straggler speculation ([`SpeculateConfig`], default off) and
    /// chaos fault injection ([`ChaosConfig`], default off). With both
    /// off this is byte-for-byte the pre-chaos coordinator — no monitor
    /// thread, no per-boundary draws, identical results, routes, and
    /// metrics.
    pub fn start_full(
        n_workers: usize,
        router: Router,
        engine_factory: Option<EngineFactory>,
        replan: ReplanConfig,
        speculate: SpeculateConfig,
        chaos: ChaosConfig,
    ) -> Self {
        Coordinator::start_traced(n_workers, router, engine_factory, replan, speculate, chaos, None)
    }

    /// [`Coordinator::start_full`] plus an optional request [`Tracer`]
    /// shared with the serving front door. `None` (every pre-existing
    /// caller) is the zero-overhead path: workers never read a clock or
    /// allocate a span.
    #[allow(clippy::too_many_arguments)]
    pub fn start_traced(
        n_workers: usize,
        router: Router,
        engine_factory: Option<EngineFactory>,
        replan: ReplanConfig,
        speculate: SpeculateConfig,
        chaos: ChaosConfig,
        tracer: Option<Arc<Tracer>>,
    ) -> Self {
        let mut router = router;
        let (tx_hash, rx_hash) = mpsc::channel::<WorkerMsg>();
        let (tx_results, rx_results) = mpsc::channel::<JobResult>();
        let rx_hash = Arc::new(Mutex::new(rx_hash));
        let metrics = Arc::new(Metrics::new());
        // one history store serves all three loops: shard-replan feedback
        // (barriers), engine-tagged dispatch measurements (workers), and
        // the router's warm-pattern dispatch reads. A caller-supplied
        // dispatch store (the serving front door's persisted history)
        // becomes that store; otherwise the coordinator owns a fresh one
        // and, under Auto dispatch, hands the router a handle to it.
        let history = match router.cfg.dispatch_history.clone() {
            Some(h) => h,
            None => {
                let h = Arc::new(Mutex::new(ExecHistory::new(replan.history_cap)));
                if router.cfg.engine_mode == EngineMode::Auto {
                    router.cfg.dispatch_history = Some(Arc::clone(&h));
                }
                h
            }
        };
        // engine tagging is strictly part of the measured dispatcher:
        // outside Auto mode the workers never touch the history, so
        // `--engine hash` (and the Fill default) reproduce the
        // pre-dispatch coordinator's history contents and gauges exactly
        let engine_history = (replan.enabled && router.cfg.engine_mode == EngineMode::Auto)
            .then(|| Arc::clone(&history));
        let fit: Option<Arc<NsPerProdFit>> = router.cfg.fit.clone();
        let replacements: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let shared = WorkerShared {
            rx: Arc::clone(&rx_hash),
            tx_requeue: tx_hash.clone(),
            tx_res: tx_results.clone(),
            metrics: Arc::clone(&metrics),
            fit,
            engine_history: engine_history.clone(),
            chaos,
            replacements: Arc::clone(&replacements),
            tracer: tracer.clone(),
        };
        let mut workers = Vec::new();
        for worker_id in 0..n_workers.max(1) {
            workers.push(spawn_hash_worker(shared.clone(), worker_id, 0));
        }

        // straggler-speculation monitor: polls in-flight barriers'
        // timing views and launches backup sub-jobs for lagging shards
        let monitor_stop = Arc::new(AtomicBool::new(false));
        let spec_registry: Arc<Mutex<Vec<Weak<ShardBarrier>>>> = Arc::new(Mutex::new(Vec::new()));
        let monitor = speculate.enabled.then(|| {
            let reg = Arc::clone(&spec_registry);
            let tx = tx_hash.clone();
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&monitor_stop);
            let tracer = tracer.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(SPECULATION_TICK);
                    let live: Vec<Arc<ShardBarrier>> = {
                        let mut g = reg.lock().unwrap_or_else(|e| e.into_inner());
                        g.retain(|w| w.strong_count() > 0);
                        g.iter().filter_map(Weak::upgrade).collect()
                    };
                    for barrier in live {
                        for plan in barrier.stragglers() {
                            metrics.speculative_launches.fetch_add(1, Ordering::Relaxed);
                            if let Some(tr) = tracer.as_ref() {
                                let trace = barrier.job_id();
                                tr.instant(
                                    trace,
                                    tr.parent_for(trace),
                                    LANE_FRONT,
                                    "speculate_launch",
                                    vec![("shard".to_string(), plan.shard.to_string())],
                                );
                            }
                            let task = ShardTask {
                                barrier: Arc::clone(&barrier),
                                shard: plan.shard,
                                lo: plan.lo,
                                hi: plan.hi,
                                a: plan.a,
                                b: plan.b,
                                b_fp: plan.b_fp,
                                measure: plan.measure,
                                attempts: 0,
                                speculative: true,
                                engine: plan.engine,
                                block_t: plan.block_t,
                            };
                            if tx.send(WorkerMsg::RunShard(task)).is_err() {
                                return;
                            }
                        }
                    }
                }
            })
        });

        let tx_block = engine_factory.map(|factory| {
            let (tx_block, rx_block) = mpsc::channel::<WorkerMsg>();
            let tx_res = tx_results.clone();
            let metrics = Arc::clone(&metrics);
            let engine_history = engine_history.clone();
            let tracer_block = tracer.clone();
            workers.push(std::thread::spawn(move || {
                // the engine (non-Send PJRT state) lives and dies here
                let mut engine = match factory() {
                    Ok(e) => Some(e),
                    Err(e) => {
                        eprintln!("block engine init failed: {e:#}");
                        None
                    }
                };
                loop {
                    match rx_block.recv() {
                        Ok(WorkerMsg::Run(job, _, t0, _)) => {
                            let span_t0 = tracer_block.as_ref().map(|t| t.now_ns());
                            // guard the stats assert: a force-routed job
                            // with mismatched dims must fail via the
                            // engine's error, not panic this thread
                            let nprod = if job.a.cols == job.b.rows {
                                total_nprod(&job.a, &job.b)
                            } else {
                                0
                            };
                            let c = match engine.as_mut() {
                                Some(e) => e.spgemm_csr(&job.a, &job.b),
                                None => Err(anyhow::anyhow!("block engine unavailable")),
                            };
                            // the block half of the engine-tagged
                            // measurement loop: fold the run's simulated
                            // ns into the pattern's block EWMA (Auto
                            // mode only, successful runs only)
                            if c.is_ok() {
                                if let (Some(h), Some(e)) =
                                    (engine_history.as_ref(), engine.as_ref())
                                {
                                    let key = (
                                        job.a.pattern_fingerprint(),
                                        job.b.pattern_fingerprint(),
                                    );
                                    let mut h = h.lock().unwrap_or_else(|e| e.into_inner());
                                    h.record(
                                        key,
                                        RunObservation {
                                            engine: Engine::Block,
                                            engine_ns: e.simulated_ns(&V100),
                                            nprod: nprod as u64,
                                            ..Default::default()
                                        },
                                    );
                                    metrics
                                        .history_patterns
                                        .store(h.len() as u64, Ordering::Relaxed);
                                    metrics
                                        .history_evictions
                                        .store(h.evictions(), Ordering::Relaxed);
                                }
                            }
                            if let (Some(tr), Some(s0)) = (tracer_block.as_ref(), span_t0) {
                                let s1 = tr.now_ns();
                                let parent = tr.parent_for(job.id);
                                tr.record(Span {
                                    trace: job.id,
                                    id: tr.next_span_id(),
                                    parent,
                                    name: "exec".to_string(),
                                    lane: LANE_BLOCK,
                                    t0_ns: s0,
                                    t1_ns: s1,
                                    args: vec![
                                        ("route".to_string(), "block".to_string()),
                                        ("nprod".to_string(), nprod.to_string()),
                                    ],
                                    error: c.is_err(),
                                    instant: false,
                                });
                            }
                            finish(&metrics, &tx_res, job.id, Route::Block, c, nprod, t0);
                        }
                        // the submit path never sends shard or batch
                        // messages to the block channel; if one ever
                        // arrives, dropping it is safe (a dropped
                        // ShardTask's barrier reports the parent failed)
                        Ok(WorkerMsg::RunShard(_)) | Ok(WorkerMsg::RunBatch(..)) => {}
                        Ok(WorkerMsg::Stop) | Err(_) => break,
                    }
                }
            }));
            tx_block
        });

        Coordinator {
            tx_hash,
            tx_block,
            rx_results,
            tx_results,
            workers,
            replacements,
            monitor,
            monitor_stop,
            speculate,
            spec_registry,
            router,
            replan,
            history,
            block_fallback_logged: AtomicBool::new(false),
            tracer,
            metrics,
        }
    }

    /// The execution history (shared with in-flight shard barriers).
    pub fn history(&self) -> &Arc<Mutex<ExecHistory>> {
        &self.history
    }

    /// Submit a job: routed here (structure-only, cheap), then queued.
    /// Latency is measured from this point, so `wall_ns` and the metric
    /// percentiles are end-to-end (queue wait included) on every route.
    pub fn submit(&self, job: Job) {
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let span_t0 = self.tracer.as_ref().map(|t| t.now_ns());
        let route = job.force_route.unwrap_or_else(|| self.router.route(&job.a, &job.b));
        let route = match (route, &self.tx_block) {
            (Route::Block, Some(_)) => Route::Block,
            (Route::Block, None) if job.force_route.is_some() => Route::Block, // honored, will fail
            (Route::Block, None) => {
                // auto-routed block job with no block engine loaded:
                // fall back to the hash pipeline, but never silently —
                // count it and log it once so an operator who expected
                // block-engine throughput can see the downgrade
                self.metrics.block_fallbacks.fetch_add(1, Ordering::Relaxed);
                if !self.block_fallback_logged.swap(true, Ordering::Relaxed) {
                    eprintln!(
                        "opsparse: block-routed job downgraded to the hash pipeline \
                         (no block engine loaded); counting further downgrades in \
                         the block_fallbacks metric"
                    );
                }
                Route::Hash
            }
            // ShardedBlock needs no dedicated block worker: each shard
            // sub-job builds its own native engine on the hash pool
            (r, _) => r,
        };
        // route-decision span: the chosen route plus both engines'
        // modeled ns, so a mis-route debugs against the very numbers
        // the dispatcher compared (the estimate re-runs here — cheap,
        // structure-only — and only when tracing is on)
        if let (Some(tr), Some(s0)) = (self.tracer.as_ref(), span_t0) {
            let s1 = tr.now_ns();
            let parent = tr.parent_for(job.id);
            let (hash_ns, block_ns) = self.router.sampled_engine_estimate(&job.a, &job.b);
            let mut args = vec![
                ("route".to_string(), format!("{route:?}")),
                ("modeled_hash_ns".to_string(), format!("{hash_ns:.0}")),
                ("modeled_block_ns".to_string(), format!("{block_ns:.0}")),
            ];
            if job.force_route.is_some() {
                args.push(("forced".to_string(), "true".to_string()));
            }
            tr.record(Span {
                trace: job.id,
                id: tr.next_span_id(),
                parent,
                name: "route_decision".to_string(),
                lane: LANE_FRONT,
                t0_ns: s0,
                t1_ns: s1,
                args,
                error: false,
                instant: false,
            });
            self.metrics.phases.route_decision.observe(s1.saturating_sub(s0));
        }
        match route {
            Route::Hash => {
                self.metrics.hash_routed.fetch_add(1, Ordering::Relaxed);
                self.tx_hash.send(WorkerMsg::Run(job, route, t0, 0)).expect("hash workers alive");
            }
            Route::Sharded { n_devices } | Route::ShardedBlock { n_devices } => {
                // split into per-shard sub-jobs that fan out across the
                // whole worker pool; a ShardBarrier stitches the row
                // blocks and emits the one parent JobResult. Block-engine
                // parents ride the same machinery with T-aligned cuts
                // and per-task native engines.
                let block = matches!(route, Route::ShardedBlock { .. });
                let engine = if block { Engine::Block } else { Engine::Hash };
                let block_t = self.router.cfg.t.max(1);
                if block {
                    self.metrics.sharded_block_routed.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.metrics.sharded_routed.fetch_add(1, Ordering::Relaxed);
                }
                let n = n_devices.max(1);
                // hash B's pattern once per parent job; every shard
                // sub-job reuses it for its shard-aware cache key, and
                // the execution history keys on (fp(A), fp(B))
                let b_fp = job.b.pattern_fingerprint();
                // adaptive re-planning: a warm pattern re-cuts its shard
                // bounds from the previous run's measured per-shard
                // times instead of the nprod proxy. Forced routes are a
                // test/bench override and bypass adaptation the same way
                // they bypass the router. Block parents keep the
                // feedback hook (their measured makespan feeds the
                // dispatcher) but always fresh-cut: a measured re-cut
                // would move the bounds off the T-alignment (measured
                // re-cuts for block plans are a ROADMAP follow-on).
                let adaptive = self.replan.enabled && job.force_route.is_none();
                let (key, measured) = if adaptive {
                    let key = (job.a.pattern_fingerprint(), b_fp);
                    let measured: Option<Vec<MeasuredShard>> = if block {
                        None
                    } else {
                        let h = self.history.lock().unwrap_or_else(|e| e.into_inner());
                        h.lookup(key)
                            .map(|s| s.measured.clone())
                            .filter(|m| !m.is_empty())
                    };
                    if !block {
                        if measured.is_some() {
                            self.metrics.replans.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.metrics.replan_cold_misses.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    (Some(key), measured)
                } else {
                    (None, None)
                };
                // planning walks both operands end to end; a malformed
                // pair (the failure-injection surface) must cost this
                // job, not the submitting thread. (An auto-routed shard
                // job also paid the router's O(nnz(A)) total fold — the
                // per-row vector is deliberately not materialized there,
                // since most submits never reach this branch.)
                let planned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let nprod = nprod_per_row(&job.a, &job.b);
                    if block {
                        ShardPlan::balanced_aligned(&nprod, n, block_t)
                    } else {
                        match &measured {
                            Some(m) => ShardPlan::from_history(&nprod, n, m),
                            None => ShardPlan::balanced(&nprod, n),
                        }
                    }
                }));
                let plan = match planned {
                    Ok(p) => p,
                    Err(_) => {
                        finish(
                            &self.metrics,
                            &self.tx_results,
                            job.id,
                            route,
                            Err(anyhow::anyhow!(
                                "shard planning panicked (malformed operands?)"
                            )),
                            0,
                            t0,
                        );
                        return;
                    }
                };
                let a = Arc::new(job.a);
                let b = Arc::new(job.b);
                let feedback = key.map(|key| ShardFeedback {
                    history: Arc::clone(&self.history),
                    key,
                    ranges: (0..n).map(|s| plan.range(s)).collect(),
                });
                let measure = feedback.is_some();
                let mut barrier = ShardBarrier::new(
                    job.id,
                    route,
                    n,
                    a.rows,
                    b.cols,
                    self.tx_results.clone(),
                    Arc::clone(&self.metrics),
                    t0,
                    feedback,
                );
                if let Some(tr) = self.tracer.as_ref() {
                    barrier.set_obs(Arc::clone(tr));
                }
                if self.speculate.enabled {
                    // attach the operand handles the monitor needs to
                    // relaunch a lagging shard (stored on the barrier,
                    // not the tasks — tasks own the barrier, and a
                    // barrier owning its tasks would be an Arc cycle)
                    barrier.set_speculation(SpeculationState {
                        cfg: self.speculate,
                        a: Arc::clone(&a),
                        b: Arc::clone(&b),
                        b_fp,
                        measure,
                        ranges: (0..n).map(|s| plan.range(s)).collect(),
                        engine,
                        block_t,
                    });
                }
                let barrier = Arc::new(barrier);
                if self.speculate.enabled {
                    self.spec_registry
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push(Arc::downgrade(&barrier));
                }
                for s in 0..n {
                    let (lo, hi) = plan.range(s);
                    self.tx_hash
                        .send(WorkerMsg::RunShard(ShardTask {
                            barrier: Arc::clone(&barrier),
                            shard: s,
                            lo,
                            hi,
                            a: Arc::clone(&a),
                            b: Arc::clone(&b),
                            b_fp,
                            measure,
                            attempts: 0,
                            speculative: false,
                            engine,
                            block_t,
                        }))
                        .expect("hash workers alive");
                }
            }
            Route::Block => {
                self.metrics.block_routed.fetch_add(1, Ordering::Relaxed);
                match &self.tx_block {
                    Some(tx) => {
                        tx.send(WorkerMsg::Run(job, route, t0, 0)).expect("block worker alive")
                    }
                    None => finish(
                        &self.metrics,
                        &self.tx_results,
                        job.id,
                        Route::Block,
                        Err(anyhow::anyhow!("no block engine loaded")),
                        0,
                        t0,
                    ),
                }
            }
        }
    }

    /// Submit several small hash jobs as **one device pass on one
    /// worker**: the members travel as a single queue message, run
    /// back-to-back against that worker's device pool and pattern cache
    /// (one visit amortizes the queue traffic and keeps every member's
    /// allocations on one pool), and each emits its own [`JobResult`] in
    /// member order. Results are bit-identical to submitting the members
    /// one at a time — batching moves work, it never changes it. Routing
    /// is **not** consulted: the caller (the serving front door's
    /// batcher) only batches jobs it already routed to the hash path;
    /// `force_route` is ignored.
    pub fn submit_batch(&self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let n = jobs.len() as u64;
        self.metrics.jobs_submitted.fetch_add(n, Ordering::Relaxed);
        self.metrics.hash_routed.fetch_add(n, Ordering::Relaxed);
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics.batched_jobs.fetch_add(n, Ordering::Relaxed);
        self.tx_hash.send(WorkerMsg::RunBatch(jobs, t0, 0)).expect("hash workers alive");
    }

    /// Receive the next completed job (blocking).
    pub fn recv(&self) -> Option<JobResult> {
        self.rx_results.recv().ok()
    }

    /// Receive the next completed job, waiting at most `timeout` —
    /// `None` on timeout or when every sender is gone. The serving
    /// front door's dispatcher polls with this so it can interleave
    /// result fan-out with admission and age-based batch flushing.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<JobResult> {
        self.rx_results.recv_timeout(timeout).ok()
    }

    /// Stop all workers and join. Stop markers queue **behind** every
    /// already-submitted job and shard sub-job on the shared FIFO, so
    /// in-flight shard barriers drain to completion before the workers
    /// exit — shutdown never strands a parent job behind a half-done
    /// barrier.
    ///
    /// Ordering matters with speculation and chaos on:
    /// 1. The speculation monitor is stopped and joined **first**, so no
    ///    backup sub-job can land behind the Stop markers (it would be
    ///    dropped unexecuted, which is harmless — the primary chain still
    ///    resolves the shard — but pointless).
    /// 2. Exactly `n` Stop markers suffice even under chaos kills,
    ///    because every death spawns exactly one replacement: the live
    ///    fleet width is always `n`.
    /// 3. Replacement handles are drained pop-until-empty *after* the
    ///    original handles join. A dying worker pushes its replacement's
    ///    handle before its own thread exits, so once all original
    ///    threads (and transitively their replacements) have returned,
    ///    the registry cannot grow again — the drain terminates.
    pub fn shutdown(self) {
        self.monitor_stop.store(true, Ordering::Relaxed);
        if let Some(m) = self.monitor {
            let _ = m.join();
        }
        for _ in &self.workers {
            let _ = self.tx_hash.send(WorkerMsg::Stop);
        }
        if let Some(tx) = &self.tx_block {
            let _ = tx.send(WorkerMsg::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
        loop {
            let h = self.replacements.lock().unwrap_or_else(|e| e.into_inner()).pop();
            match h {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn hash_jobs_roundtrip_through_the_pool() {
        let coord = Coordinator::start(4, Router::default(), None);
        let mut rng = Rng::new(71);
        let mats: Vec<Csr> = (0..8)
            .map(|_| Uniform { n: 120, per_row: 6, jitter: 3 }.generate(&mut rng))
            .collect();
        for (i, m) in mats.iter().enumerate() {
            coord.submit(Job { id: i as u64, a: m.clone(), b: m.clone(), force_route: None });
        }
        let mut results = Vec::new();
        for _ in 0..8 {
            results.push(coord.recv().unwrap());
        }
        for r in &results {
            let m = &mats[r.id as usize];
            let gold = spgemm_reference(m, m);
            assert!(r.c.as_ref().unwrap().approx_eq(&gold, 1e-12), "job {}", r.id);
            assert_eq!(r.route, Route::Hash);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 8);
        assert_eq!(snap.jobs_failed, 0);
        assert!(snap.p50_ns.is_some());
        coord.shutdown();
    }

    #[test]
    fn repeated_pattern_hits_symbolic_cache_and_pool() {
        // one worker so every job lands on the same pool + cache
        let coord = Coordinator::start(1, Router::default(), None);
        let mut rng = Rng::new(72);
        let a = Uniform { n: 200, per_row: 8, jitter: 4 }.generate(&mut rng);
        for id in 0..4u64 {
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: None });
        }
        let gold = spgemm_reference(&a, &a);
        for _ in 0..4 {
            let r = coord.recv().unwrap();
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.sym_cache_misses, 1, "only the first job computes symbolic");
        assert_eq!(snap.sym_cache_hits, 3, "repeats must hit the cache");
        assert!(snap.pool_hits > 0, "warm jobs must recycle pool buckets");
        assert!(snap.pool_reused_bytes > 0);
        assert!(snap.pool_device_mallocs > 0, "the cold job grows the pool");
        coord.shutdown();
    }

    #[test]
    fn batched_submission_is_bit_identical_to_singletons_and_ordered() {
        let mut rng = Rng::new(81);
        let mats: Vec<Csr> = (0..5)
            .map(|_| Uniform { n: 100, per_row: 5, jitter: 2 }.generate(&mut rng))
            .collect();
        // singleton reference pass (same worker count, fresh state)
        let solo_coord = Coordinator::start(1, Router::default(), None);
        for (i, m) in mats.iter().enumerate() {
            solo_coord.submit(Job {
                id: i as u64,
                a: m.clone(),
                b: m.clone(),
                force_route: None,
            });
        }
        let mut solo: Vec<Option<Csr>> = vec![None; mats.len()];
        for _ in 0..mats.len() {
            let r = solo_coord.recv().unwrap();
            solo[r.id as usize] = Some(r.c.unwrap());
        }
        solo_coord.shutdown();
        // batched pass: one message, one worker visit
        let coord = Coordinator::start(1, Router::default(), None);
        coord.submit_batch(
            mats.iter()
                .enumerate()
                .map(|(i, m)| Job {
                    id: i as u64,
                    a: m.clone(),
                    b: m.clone(),
                    force_route: None,
                })
                .collect(),
        );
        for want_id in 0..mats.len() as u64 {
            let r = coord.recv().unwrap();
            assert_eq!(r.id, want_id, "batch members complete in member order");
            assert_eq!(r.route, Route::Hash);
            let got = r.c.unwrap();
            assert_eq!(&got, solo[r.id as usize].as_ref().unwrap(), "bitwise identical");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.batches, 1);
        assert_eq!(snap.batched_jobs, 5);
        assert_eq!(snap.jobs_submitted, 5);
        assert_eq!(snap.jobs_completed, 5);
        assert_eq!(snap.hash_routed, 5);
        coord.shutdown();
        // an empty batch is a no-op, not a message
        let c2 = Coordinator::start(1, Router::default(), None);
        c2.submit_batch(Vec::new());
        assert_eq!(c2.metrics.snapshot().batches, 0);
        c2.shutdown();
    }

    #[test]
    fn bad_job_reports_failure_not_panic() {
        let coord = Coordinator::start(2, Router::default(), None);
        // dimension mismatch
        coord.submit(Job { id: 1, a: Csr::zero(3, 4), b: Csr::zero(5, 5), force_route: None });
        let r = coord.recv().unwrap();
        assert!(r.c.is_err());
        assert_eq!(coord.metrics.snapshot().jobs_failed, 1);
        coord.shutdown();
    }

    #[test]
    fn oversized_jobs_shard_and_reassemble_exactly() {
        use crate::coordinator::router::RouterConfig;
        // a budget far below any real working set: every job shards
        // (memory-only routing — these matrices are small enough that the
        // cost-aware router would rightly decline to replicate B)
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let coord = Coordinator::start(2, router, None);
        let mut rng = Rng::new(73);
        let a = Uniform { n: 300, per_row: 8, jitter: 4 }.generate(&mut rng);
        for id in 0..3u64 {
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: None });
        }
        let gold = spgemm_reference(&a, &a);
        for _ in 0..3 {
            let r = coord.recv().unwrap();
            assert!(matches!(r.route, Route::Sharded { n_devices } if n_devices >= 2));
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
            assert!(r.nprod > 0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.sharded_routed, 3);
        assert_eq!(snap.jobs_completed, 3);
        // sharded traffic must show up in the pool telemetry: cold jobs
        // grow per-device pools, and with 3 jobs on 2 workers some worker
        // runs warm at least once
        assert!(snap.pool_device_mallocs > 0, "cold sharded jobs grow the pools");
        assert!(snap.pool_hits > 0, "warm sharded jobs must recycle pool buckets");
        coord.shutdown();
    }

    #[test]
    fn sharded_job_fans_out_across_distinct_workers() {
        // the acceptance property of the cross-worker fan-out: with >= 2
        // workers, one sharded job's sub-jobs execute on >= 2 distinct
        // workers (observable via telemetry). Several multi-millisecond
        // jobs keep the queue busy long enough that the second worker
        // always participates, whatever the thread scheduler does.
        let coord = Coordinator::start(2, Router::default(), None);
        let mut rng = Rng::new(76);
        let a = Uniform { n: 1200, per_row: 8, jitter: 4 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for id in 0..3u64 {
            coord.submit(Job {
                id,
                a: a.clone(),
                b: a.clone(),
                force_route: Some(Route::Sharded { n_devices: 8 }),
            });
        }
        for _ in 0..3 {
            let r = coord.recv().unwrap();
            assert_eq!(r.route, Route::Sharded { n_devices: 8 });
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
            assert!(r.nprod > 0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 3);
        assert_eq!(snap.shard_subjobs, 24, "every sub-job must be accounted");
        assert!(
            snap.shard_workers >= 2,
            "shards must spread over the pool, got {} worker(s)",
            snap.shard_workers
        );
        coord.shutdown();
    }

    #[test]
    fn repeated_sharded_pattern_hits_shard_aware_cache() {
        // one worker, so every shard sub-job lands on the same cache:
        // the first sharded job computes (and caches) each shard's
        // symbolic phase, every repeat replays all of them
        let coord = Coordinator::start(1, Router::default(), None);
        let mut rng = Rng::new(77);
        let a = Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for id in 0..3u64 {
            coord.submit(Job {
                id,
                a: a.clone(),
                b: a.clone(),
                force_route: Some(Route::Sharded { n_devices: 4 }),
            });
        }
        for _ in 0..3 {
            let r = coord.recv().unwrap();
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(
            snap.shard_sym_cache_hits + snap.shard_sym_cache_misses,
            12,
            "every shard sub-job consults the shard-aware cache"
        );
        assert!(
            snap.shard_sym_cache_misses <= 4,
            "only the first job may compute symbolic phases, got {} misses",
            snap.shard_sym_cache_misses
        );
        assert!(
            snap.shard_sym_cache_hits >= 8,
            "both repeats must replay every shard, got {} hits",
            snap.shard_sym_cache_hits
        );
        // whole-job cache counters are untouched by shard sub-jobs
        assert_eq!(snap.sym_cache_hits + snap.sym_cache_misses, 0);
        coord.shutdown();
    }

    #[test]
    fn warm_sharded_pattern_replans_from_history() {
        use crate::coordinator::feedback::NsPerProdFit;
        use crate::coordinator::router::RouterConfig;
        // a live fit + a budget far below any real working set: every
        // auto-routed job shards, and repeats of the pattern re-cut from
        // the history the first run recorded
        let fit = Arc::new(NsPerProdFit::new(1.0));
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            fit: Some(Arc::clone(&fit)),
            ..Default::default()
        });
        let coord = Coordinator::start(2, router, None);
        let mut rng = Rng::new(78);
        let a = Uniform { n: 300, per_row: 8, jitter: 4 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        // sequential submit→recv so each repeat sees the recorded history
        for id in 0..3u64 {
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: None });
            let r = coord.recv().unwrap();
            assert!(matches!(r.route, Route::Sharded { .. }));
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12), "job {id}: replanned result wrong");
        }
        // the §1 workloads also send ordinary hash traffic, which feeds
        // the online ns_per_prod re-fit
        coord.submit(Job {
            id: 99,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Hash),
        });
        assert!(coord.recv().unwrap().c.is_ok());
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.replan_cold_misses, 1, "only the first submit is cold");
        assert_eq!(snap.replans, 2, "every repeat must consult the history");
        assert_eq!(snap.history_patterns, 1, "one pattern held");
        assert_eq!(snap.history_evictions, 0);
        assert!(snap.refit_updates >= 1, "measured hash traffic must fold into the fit");
        assert_eq!(fit.updates(), snap.refit_updates, "metric mirrors the fit");
        coord.shutdown();
    }

    #[test]
    fn replan_off_is_the_proxy_planned_baseline() {
        use crate::coordinator::feedback::ReplanConfig;
        use crate::coordinator::router::RouterConfig;
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let coord = Coordinator::start_with(1, router, None, ReplanConfig::off());
        let mut rng = Rng::new(79);
        let a = Uniform { n: 250, per_row: 7, jitter: 3 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for id in 0..2u64 {
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: None });
            let r = coord.recv().unwrap();
            assert!(matches!(r.route, Route::Sharded { .. }));
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.replans, 0, "ablation baseline must never replan");
        assert_eq!(snap.replan_cold_misses, 0, "… or even consult the history");
        assert_eq!(snap.history_patterns, 0, "… or record into it");
        assert_eq!(snap.refit_updates, 0, "no fit attached, nothing folded");
        assert!(coord.history().lock().unwrap().is_empty());
        coord.shutdown();
    }

    #[test]
    fn forced_sharded_route_is_honored() {
        let coord = Coordinator::start(1, Router::default(), None);
        let mut rng = Rng::new(74);
        let a = Uniform { n: 200, per_row: 6, jitter: 3 }.generate(&mut rng);
        coord.submit(Job {
            id: 5,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Sharded { n_devices: 3 }),
        });
        let r = coord.recv().unwrap();
        assert_eq!(r.route, Route::Sharded { n_devices: 3 });
        let gold = spgemm_reference(&a, &a);
        assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
        coord.shutdown();
    }

    #[test]
    fn block_route_without_engine_fails_gracefully() {
        let coord = Coordinator::start(1, Router::default(), None);
        let m = Csr::identity(32);
        coord.submit(Job { id: 9, a: m.clone(), b: m, force_route: Some(Route::Block) });
        let r = coord.recv().unwrap();
        assert!(r.c.is_err());
        assert_eq!(r.route, Route::Block);
        coord.shutdown();
    }

    #[test]
    fn sharded_block_jobs_stitch_bit_identical_to_unsharded_block() {
        use crate::gen::banded::Banded;
        // the ShardedBlock acceptance property: T-aligned cuts + per-shard
        // native engines stitch to exactly the unsharded block result,
        // which is itself bitwise the hash result (the native backend is
        // bit-exact) — so all engine/shard combinations agree
        let coord = Coordinator::start(2, Router::default(), None);
        let mut rng = Rng::new(82);
        let a = Banded { n: 500, per_row: 24, band: 20, contiguous_frac: 1.0 }.generate(&mut rng);
        let gold_block = BlockEngine::native(SHARD_BLOCK_P, 16).unwrap().spgemm_csr(&a, &a).unwrap();
        let gold = spgemm_reference(&a, &a);
        for id in 0..2u64 {
            coord.submit(Job {
                id,
                a: a.clone(),
                b: a.clone(),
                force_route: Some(Route::ShardedBlock { n_devices: 3 }),
            });
        }
        for _ in 0..2 {
            let r = coord.recv().unwrap();
            assert_eq!(r.route, Route::ShardedBlock { n_devices: 3 });
            let c = r.c.unwrap();
            assert_eq!(c, gold_block, "stitched shards must match the unsharded block engine");
            assert!(c.approx_eq(&gold, 1e-12));
            assert!(r.nprod > 0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.sharded_block_routed, 2);
        assert_eq!(snap.sharded_routed, 0, "block parents get their own counter");
        assert_eq!(snap.shard_subjobs, 6, "every block sub-job must be accounted");
        assert_eq!(snap.block_fallbacks, 0, "no block worker needed: shards self-build engines");
        coord.shutdown();
    }

    #[test]
    fn auto_block_route_without_engine_falls_back_and_counts() {
        use crate::coordinator::router::RouterConfig;
        use crate::gen::banded::Banded;
        // an auto-routed block job with no block engine loaded must
        // succeed via the hash pipeline — downgraded loudly (counted),
        // never silently, and never failed (forced routes still fail;
        // see block_route_without_engine_fails_gracefully above)
        let router =
            Router::new(RouterConfig { engine_mode: EngineMode::Block, ..Default::default() });
        let coord = Coordinator::start(1, router, None);
        let mut rng = Rng::new(83);
        let a = Banded { n: 200, per_row: 12, band: 10, contiguous_frac: 1.0 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for id in 0..2u64 {
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: None });
        }
        for _ in 0..2 {
            let r = coord.recv().unwrap();
            assert_eq!(r.route, Route::Hash, "downgraded, not failed");
            assert!(r.c.unwrap().approx_eq(&gold, 1e-12));
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.block_fallbacks, 2, "every downgrade is counted");
        assert_eq!(snap.hash_routed, 2);
        assert_eq!(snap.block_routed, 0);
        assert_eq!(snap.jobs_failed, 0);
        coord.shutdown();
    }

    #[test]
    fn measured_dispatch_records_engine_tagged_history() {
        use crate::coordinator::router::RouterConfig;
        use crate::gen::banded::Banded;
        // the measured-dispatch loop end to end: under Auto, a blocky
        // pattern routes to the block engine and its run lands in the
        // pattern's block EWMA; a scattered pattern routes to hash and
        // warms the hash EWMA — so the next decision for either pattern
        // compares measurements, not estimates
        let router =
            Router::new(RouterConfig { engine_mode: EngineMode::Auto, ..Default::default() });
        let coord =
            Coordinator::start(1, router, Some(Box::new(|| BlockEngine::native(16, 16))));
        let mut rng = Rng::new(84);
        let blocky =
            Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let scattered = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        coord.submit(Job { id: 0, a: blocky.clone(), b: blocky.clone(), force_route: None });
        let r = coord.recv().unwrap();
        assert_eq!(r.route, Route::Block, "cold estimate sends the blocky pattern to block");
        assert!(r.c.unwrap().approx_eq(&spgemm_reference(&blocky, &blocky), 1e-12));
        coord.submit(Job {
            id: 1,
            a: scattered.clone(),
            b: scattered.clone(),
            force_route: None,
        });
        let r = coord.recv().unwrap();
        assert_eq!(r.route, Route::Hash, "cold estimate keeps the scattered pattern on hash");
        assert!(r.c.is_ok());
        let h = coord.history().lock().unwrap();
        let bs = h
            .lookup((blocky.pattern_fingerprint(), blocky.pattern_fingerprint()))
            .expect("blocky pattern recorded");
        assert!(bs.block.warm() && bs.block.runs >= 1, "block run measured: {:?}", bs.block);
        let ss = h
            .lookup((scattered.pattern_fingerprint(), scattered.pattern_fingerprint()))
            .expect("scattered pattern recorded");
        assert!(ss.hash.warm() && ss.hash.runs >= 1, "hash run measured: {:?}", ss.hash);
        drop(h);
        coord.shutdown();
    }
}
