//! Per-block duration model.
//!
//! A thread block's runtime on an SM is the max of its bottleneck
//! components (memory-bound model, Roofline-style [20]), scaled by the
//! SM-sharing factor: with `r` blocks resident per SM, each block gets
//! `1/r` of the SM's throughput, and the whole SM's achieved throughput is
//! discounted by the latency-hiding factor of the kernel's occupancy
//! (§4.7: SpGEMM is memory-bound and irregular, so occupancy is critical).

use super::device::DeviceParams;
use super::occupancy::{blocks_per_sm, latency_hiding, occupancy};
use super::trace::{BlockWork, Kernel};

/// Static per-kernel cost context, computed once per launch.
#[derive(Clone, Copy, Debug)]
pub struct KernelCost {
    /// Resident blocks per SM (occupancy limit).
    pub residency: usize,
    /// Theoretical occupancy (0..1).
    pub occupancy: f64,
    /// Latency-hiding throughput factor (0..1).
    pub lh: f64,
}

impl KernelCost {
    pub fn of(k: &Kernel, dev: &DeviceParams) -> Self {
        let residency = blocks_per_sm(k.tb_size, k.shared_bytes, dev).max(1);
        let occ = occupancy(k.tb_size, k.shared_bytes, dev);
        KernelCost { residency, occupancy: occ, lh: latency_hiding(occ) }
    }

    /// Duration in ns of one block with work `w`, assuming the SM is
    /// shared by `residency` blocks of this kernel.
    pub fn block_ns(&self, w: &BlockWork, dev: &DeviceParams) -> f64 {
        let share = self.residency as f64;
        // global memory: per-SM HBM share, discounted by latency hiding,
        // divided among resident blocks
        let mem = w.global_bytes as f64 / (dev.hbm_per_sm() * self.lh / share);
        // shared memory: per-SM banked throughput with the bank-conflict
        // penalty of the hash tables' random pattern; like HBM, the
        // banked pipeline needs resident warps to stay saturated, so the
        // occupancy latency-hiding factor applies (§4.7)
        let shared = w.shared_accesses as f64 * dev.bank_conflict_factor
            / (dev.shared_words_per_ns * self.lh / share);
        // fp64 pipeline
        let flop = w.flops as f64 / (dev.fp64_flops_per_ns / share);
        // contended global atomics serialize through L2
        let atomic = w.global_atomics as f64 * dev.global_atomic_ns;
        // integer mod in the probe loop: ~4 extra cycles per op, across
        // the block's warps (small; kept for the §5.2 pow2-vs-mod ablation)
        let modc = w.mod_ops as f64 * 0.02;
        mem.max(shared).max(flop) + atomic + modc + dev.block_overhead_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;

    fn kernel(tb: usize, shared: usize) -> Kernel {
        Kernel {
            name: "k".into(),
            step: "symbolic",
            stream: 0,
            tb_size: tb,
            shared_bytes: shared,
            blocks: vec![],
        }
    }

    #[test]
    fn memory_bound_block_scales_with_bytes() {
        let k = kernel(256, 8 * 1024);
        let c = KernelCost::of(&k, &V100);
        let w1 = BlockWork { global_bytes: 10_000, ..Default::default() };
        let w2 = BlockWork { global_bytes: 20_000, ..Default::default() };
        let t1 = c.block_ns(&w1, &V100);
        let t2 = c.block_ns(&w2, &V100);
        assert!(t2 > t1 * 1.5, "doubling bytes should nearly double time");
    }

    #[test]
    fn low_occupancy_is_slower_per_byte() {
        // 96KB kernel (1 block/SM, 50% occupancy) vs 48KB kernel (2/SM, full)
        let w = BlockWork { global_bytes: 1_000_000, ..Default::default() };
        let full = KernelCost::of(&kernel(1024, 48 * 1024), &V100);
        let half = KernelCost::of(&kernel(1024, 96 * 1024 - 4), &V100);
        // per-SM throughput: full has 2 blocks sharing, so per-block time
        // doubles, but per-SM bytes/ns is higher at full occupancy.
        let t_full_sm = full.block_ns(&w, &V100); // 2 blocks run concurrently
        let t_half_sm = half.block_ns(&w, &V100);
        // compare SM throughput: full processes 2 blocks in t_full_sm
        let full_bw = 2.0 * w.global_bytes as f64 / t_full_sm;
        let half_bw = w.global_bytes as f64 / t_half_sm;
        assert!(full_bw > half_bw, "full occupancy should beat 50%: {full_bw} vs {half_bw}");
    }

    #[test]
    fn atomics_add_serial_cost() {
        let k = kernel(1024, 0);
        let c = KernelCost::of(&k, &V100);
        let quiet = BlockWork::default();
        let noisy = BlockWork { global_atomics: 1000, ..Default::default() };
        let dt = c.block_ns(&noisy, &V100) - c.block_ns(&quiet, &V100);
        assert!((dt - 1000.0 * V100.global_atomic_ns).abs() < 1.0);
    }

    #[test]
    fn shared_traffic_pays_bank_conflicts() {
        let k = kernel(256, 4096);
        let c = KernelCost::of(&k, &V100);
        let w = BlockWork { shared_accesses: 1_000_000, ..Default::default() };
        let t = c.block_ns(&w, &V100);
        // must exceed the conflict-free time
        let conflict_free =
            1_000_000.0 / (V100.shared_words_per_ns / c.residency as f64);
        assert!(t > conflict_free * 1.5);
    }
}
