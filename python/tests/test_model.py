"""L2/AOT checks: model output shapes, HLO-text lowering, and execution of
the lowered computation through jax's own runtime (the same HLO the Rust
PJRT client loads)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import block_pair_matmul_ref, row_window_accumulate_ref

jax.config.update("jax_enable_x64", True)


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float64)


def test_block_engine_model_shape_and_value():
    a = rand((8, 16, 16), 1)
    b = rand((8, 16, 16), 2)
    (out,) = model.block_engine_model(a, b)
    assert out.shape == (8, 16, 16)
    np.testing.assert_allclose(out, block_pair_matmul_ref(a, b), rtol=1e-12)


def test_row_window_model_shape_and_value():
    a = rand((4, 8), 3)
    b = rand((4, 8, 32), 4)
    (out,) = model.row_window_model(a, b)
    assert out.shape == (4, 32)
    np.testing.assert_allclose(out, row_window_accumulate_ref(a, b), rtol=1e-12)


def test_hlo_text_lowering_nonempty_and_parsable_header():
    text = aot.lower_block_engine(4, 8)
    assert "HloModule" in text
    assert "f64" in text
    text2 = aot.lower_row_window(4, 8, 16)
    assert "HloModule" in text2


def test_specs_match_model():
    specs = model.block_engine_specs(4, 8)
    assert specs[0].shape == (4, 8, 8)
    rspecs = model.row_window_specs(2, 4, 16)
    assert rspecs[1].shape == (2, 4, 16)
