//! Refreshable `ns_per_prod` calibration: the online counterpart of the
//! startup least-squares fit.
//!
//! The router's shard-vs-stay decision weighs modeled transfer time
//! against compute estimated as `n_prod × ns_per_prod`. The startup
//! calibration fits that constant from *simulated* generator-suite
//! timelines — but the write-once `OnceLock` table it used to live in
//! could never be refreshed in-process, so the router kept planning with
//! a stale constant while real measured job times flowed past it. This
//! module replaces the frozen table with [`NsPerProdFit`]: the same
//! deterministic startup fit as the initial value, plus an
//! exponentially-weighted fold of measured `(execution ns, n_prod)`
//! observations. The router reads the current fit **per decision**
//! ([`crate::coordinator::RouterConfig::with_live_fit`]), so routing
//! tracks the fleet it actually runs on. Reads without intervening
//! observations are bit-stable — a fit is only moved by `observe`.

use std::sync::{Arc, OnceLock, RwLock};

/// Physically plausible band for the fit, matching the startup
/// calibration's clamp: one intermediate product costs at least a
/// fraction of an HBM access and at most a page of them.
pub const NS_PER_PROD_MIN: f64 = 0.05;
pub const NS_PER_PROD_MAX: f64 = 50.0;

/// Weight of one new observation in the exponentially-weighted fold.
const EWMA_ALPHA: f64 = 0.25;

#[derive(Clone, Copy, Debug)]
struct Fit {
    k: f64,
    updates: u64,
}

/// A refreshable ns-per-product fit: seeded with a deterministic value
/// (the startup calibration, or a caller-chosen constant) and folded
/// forward by measured observations. Cheap to share (`Arc`) between the
/// router (reads) and the coordinator's workers (writes).
#[derive(Debug)]
pub struct NsPerProdFit {
    state: RwLock<Fit>,
}

impl NsPerProdFit {
    /// A fit seeded at `initial` (clamped to the plausible band).
    pub fn new(initial: f64) -> Self {
        let k = if initial.is_finite() {
            initial.clamp(NS_PER_PROD_MIN, NS_PER_PROD_MAX)
        } else {
            1.0
        };
        NsPerProdFit { state: RwLock::new(Fit { k, updates: 0 }) }
    }

    /// A fit seeded from the simulated generator-suite calibration
    /// ([`crate::coordinator::router::calibrate_ns_per_prod`]).
    pub fn calibrated() -> Self {
        NsPerProdFit::new(crate::coordinator::router::fit_ns_per_prod_suite())
    }

    /// Rebuild a fit from a persisted snapshot (see
    /// [`NsPerProdFit::state`]): `k` is taken verbatim apart from the
    /// usual finite/band guard, and since every persisted `k` was
    /// already produced inside the band by `new`/`observe`, the clamp is
    /// the identity there — a save → reload round trip is bit-stable.
    pub fn from_state(k: f64, updates: u64) -> Self {
        let k =
            if k.is_finite() { k.clamp(NS_PER_PROD_MIN, NS_PER_PROD_MAX) } else { 1.0 };
        NsPerProdFit { state: RwLock::new(Fit { k, updates }) }
    }

    /// Snapshot `(k, updates)` for persistence — the exact pair
    /// [`NsPerProdFit::from_state`] restores.
    pub fn state(&self) -> (f64, u64) {
        let st = self.state.read().unwrap_or_else(|e| e.into_inner());
        (st.k, st.updates)
    }

    /// The current fit. Bit-stable across repeated reads with no
    /// intervening [`NsPerProdFit::observe`].
    pub fn current(&self) -> f64 {
        self.state.read().unwrap_or_else(|e| e.into_inner()).k
    }

    /// Observations folded in so far.
    pub fn updates(&self) -> u64 {
        self.state.read().unwrap_or_else(|e| e.into_inner()).updates
    }

    /// Fold one measured job into the fit: `exec_ns` of compute over
    /// `nprod` intermediate products. Returns `false` (and leaves the
    /// fit untouched) for unusable samples — zero products, non-finite
    /// or non-positive times. A sample whose implied per-product cost
    /// falls outside the plausible band is *clamped* to it before
    /// folding, so outliers (queue storms, trivial jobs) can nudge the
    /// fit toward the band edge but never poison it past physics.
    pub fn observe(&self, exec_ns: f64, nprod: u64) -> bool {
        if nprod == 0 || !exec_ns.is_finite() || exec_ns <= 0.0 {
            return false;
        }
        let k_obs = (exec_ns / nprod as f64).clamp(NS_PER_PROD_MIN, NS_PER_PROD_MAX);
        let mut st = self.state.write().unwrap_or_else(|e| e.into_inner());
        st.k = (1.0 - EWMA_ALPHA) * st.k + EWMA_ALPHA * k_obs;
        st.updates += 1;
        true
    }
}

/// The process-wide default fit, seeded lazily from the simulated-suite
/// calibration on first use and returned as a shared handle — attach it
/// to a router ([`crate::coordinator::RouterConfig::with_live_fit`]) so
/// the expensive suite fit runs once per process, however many routers
/// and coordinators share it. The `OnceLock` holds the *refreshable
/// fit*, not a frozen value: observations folded into the handle move
/// every subsequent read (including
/// [`crate::coordinator::router::calibrate_ns_per_prod`] snapshots —
/// "calibrated" means the process's *current* calibration, by design),
/// which the old write-once `f64` table could not do.
pub fn default_fit() -> Arc<NsPerProdFit> {
    static FIT: OnceLock<Arc<NsPerProdFit>> = OnceLock::new();
    Arc::clone(FIT.get_or_init(|| Arc::new(NsPerProdFit::calibrated())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_reads_without_observations_are_bit_stable() {
        // the regression the OnceLock replacement must keep: a fit that
        // nobody feeds never drifts
        let f = NsPerProdFit::new(1.25);
        let k0 = f.current();
        for _ in 0..32 {
            assert_eq!(f.current(), k0, "read must not move the fit");
        }
        assert_eq!(f.updates(), 0);
        // ... and after one observation, reads are bit-stable again
        assert!(f.observe(2000.0, 1000));
        let k1 = f.current();
        assert_ne!(k1, k0);
        for _ in 0..32 {
            assert_eq!(f.current(), k1);
        }
        assert_eq!(f.updates(), 1);
    }

    #[test]
    fn observations_move_the_fit_toward_the_measured_rate() {
        let f = NsPerProdFit::new(0.1);
        for _ in 0..64 {
            assert!(f.observe(10_000.0, 1000)); // 10 ns/product
        }
        let k = f.current();
        assert!((k - 10.0).abs() < 0.1, "EWMA must converge near 10, got {k}");
        assert_eq!(f.updates(), 64);
    }

    #[test]
    fn junk_samples_are_rejected() {
        let f = NsPerProdFit::new(1.0);
        assert!(!f.observe(1000.0, 0), "zero products");
        assert!(!f.observe(f64::NAN, 10), "non-finite time");
        assert!(!f.observe(-5.0, 10), "negative time");
        assert!(!f.observe(0.0, 10), "zero time");
        assert_eq!(f.current(), 1.0, "rejected samples must not move the fit");
        assert_eq!(f.updates(), 0);
    }

    #[test]
    fn outliers_are_clamped_to_the_band_not_folded_raw() {
        let f = NsPerProdFit::new(1.0);
        assert!(f.observe(1e12, 1), "outliers fold clamped, not rejected");
        let k = f.current();
        assert!(k <= 0.75 + 0.25 * NS_PER_PROD_MAX + 1e-12, "one step toward the cap at most");
        // even an endless storm of garbage cannot push the fit past physics
        for _ in 0..256 {
            f.observe(1e12, 1);
        }
        assert!(f.current() <= NS_PER_PROD_MAX);
        for _ in 0..256 {
            f.observe(1.0, 1_000_000);
        }
        assert!(f.current() >= NS_PER_PROD_MIN);
    }

    #[test]
    fn state_round_trip_is_bit_stable() {
        let f = NsPerProdFit::new(1.0);
        for i in 1..=17u64 {
            assert!(f.observe(1000.0 * i as f64, 300 * i));
        }
        let (k, updates) = f.state();
        assert_eq!(updates, 17);
        let g = NsPerProdFit::from_state(k, updates);
        let (k2, u2) = g.state();
        assert_eq!(k.to_bits(), k2.to_bits(), "restored k must be bitwise identical");
        assert_eq!(u2, 17);
        assert_eq!(g.current().to_bits(), f.current().to_bits());
        // a tampered out-of-band snapshot is clamped, not trusted
        assert_eq!(NsPerProdFit::from_state(1e9, 3).current(), NS_PER_PROD_MAX);
        assert_eq!(NsPerProdFit::from_state(f64::NAN, 3).current(), 1.0);
    }

    #[test]
    fn seed_is_clamped_to_the_band() {
        assert_eq!(NsPerProdFit::new(1e9).current(), NS_PER_PROD_MAX);
        assert_eq!(NsPerProdFit::new(1e-9).current(), NS_PER_PROD_MIN);
        assert_eq!(NsPerProdFit::new(f64::NAN).current(), 1.0);
    }
}
