//! Numeric step (paper §5.6.2, Algorithm 5): compute each output row's
//! column indices and values with per-bin hash kernels, then condense and
//! sort into the allocated CSR arrays.
//!
//! Rows are binned by their exact `n_nz` (known from the symbolic step),
//! so no fallback/recompute is needed: rows beyond kernel6's range go
//! straight to the global-table kernel7.

use super::binning::BinningResult;
use super::hash_table::{HashAccumulator, ProbeStats};
use super::kernel_tables::{numeric_kernels, KernelConfig, NUM_SLOT_BYTES};
use super::HashVariant;
use crate::gpusim::trace::{BlockWork, Kernel};
use crate::sparse::Csr;

/// Result of the numeric step.
#[derive(Clone, Debug)]
pub struct NumericOutput {
    /// The finished result matrix.
    pub c: Csr,
    /// Aggregate probe statistics (Fig 9 metric).
    pub stats: ProbeStats,
    /// Per-bin kernels (largest bins first; global kernel7 first of all,
    /// matching the paper's launch-order rule §5.5).
    pub kernels: Vec<Kernel>,
}

/// log2-ish sorting cost of the condense+sort phase in shared accesses.
fn sort_accesses(nnz: u64) -> u64 {
    if nnz <= 1 {
        return nnz;
    }
    let stages = 64 - (nnz - 1).leading_zeros() as u64; // ceil(log2)
    2 * nnz * stages
}

/// Compute the numeric step. `c_rpt` is the exclusive sum of per-row nnz
/// (the real `C.rpt`); `binning` is over the per-row nnz with the numeric
/// ranges.
pub fn numeric_step(
    a: &Csr,
    b: &Csr,
    c_rpt: &[usize],
    binning: &BinningResult,
    variant: HashVariant,
    step: &'static str,
    num_streams: usize,
) -> NumericOutput {
    // L2 reuse discount on B-row traffic (see symbolic_step)
    let nprod_total: usize = (0..a.rows)
        .map(|i| a.row_cols(i).iter().map(|&k| b.row_nnz(k as usize)).sum::<usize>())
        .sum();
    let b_reuse = (b.nnz() as f64 / nprod_total.max(1) as f64).clamp(0.15, 1.0);
    let configs = numeric_kernels();
    let nnz_total = *c_rpt.last().unwrap();
    let mut c_col = vec![0u32; nnz_total];
    let mut c_val = vec![0f64; nnz_total];
    let mut stats = ProbeStats::default();
    let mut kernels: Vec<Kernel> = Vec::new();

    // launch order: global-table kernel7 first (its single giant rows run
    // longest), then bin6 .. bin0 (§5.5)
    let bin_order: Vec<usize> = (0..super::kernel_tables::NUM_BINS).rev().collect();
    let mut stream = 0usize;

    let mut row_cols: Vec<u32> = Vec::new();
    let mut row_vals: Vec<f64> = Vec::new();

    for &bin in &bin_order {
        let rows = binning.bin_rows(bin);
        if rows.is_empty() {
            continue;
        }
        let cfg: &KernelConfig = &configs[bin.min(7)];
        let mut blocks: Vec<BlockWork> = Vec::with_capacity(rows.len() / cfg.rows_per_block + 1);
        let mut group = BlockWork::default();
        let mut in_group = 0usize;

        // shared-table kernels reuse one accumulator across all their
        // rows (O(1) epoch reset): allocating one per row dominated the
        // numeric hot loop on many-row matrices (§Perf)
        let mut shared_table = cfg.table_size.map(|t| HashAccumulator::new(t, variant));
        let mut global_table_store: Option<HashAccumulator> = None;

        for &r in rows {
            let r = r as usize;
            let row_nnz = c_rpt[r + 1] - c_rpt[r];
            let (t_size, global_table) = match cfg.table_size {
                Some(t) => (t, false),
                // kernel7: global table sized 2x the next pow2 of the nnz
                None => (row_nnz.next_power_of_two().max(1024) * 2, true),
            };
            let table: &mut HashAccumulator = if global_table {
                // per-row global tables vary in size; keep the one with
                // carried stats and grow when needed
                match global_table_store.as_mut() {
                    Some(t) if t.t_size() >= t_size => {
                        t.reset();
                    }
                    _ => {
                        let mut fresh = HashAccumulator::new(t_size, variant);
                        if let Some(old) = global_table_store.take() {
                            fresh.stats = old.stats;
                        }
                        global_table_store = Some(fresh);
                    }
                }
                global_table_store.as_mut().unwrap()
            } else {
                let t = shared_table.as_mut().unwrap();
                t.reset();
                t
            };
            let before = table.stats;
            let (acols, avals) = a.row(r);
            for (&k, &av) in acols.iter().zip(avals) {
                let (bcols, bvals) = b.row(k as usize);
                for (&c, &bv) in bcols.iter().zip(bvals) {
                    let ok = table.insert_numeric(c, av * bv);
                    assert!(ok, "numeric table overflow: row {r} nnz {row_nnz} t_size {t_size}");
                }
            }
            // condense + sort into the output arrays
            row_cols.clear();
            row_vals.clear();
            table.condense_sorted(&mut row_cols, &mut row_vals);
            debug_assert_eq!(row_cols.len(), row_nnz, "row {r}");
            c_col[c_rpt[r]..c_rpt[r + 1]].copy_from_slice(&row_cols);
            c_val[c_rpt[r]..c_rpt[r + 1]].copy_from_slice(&row_vals);

            let delta = ProbeStats {
                inserts: table.stats.inserts - before.inserts,
                probe_iters: table.stats.probe_iters - before.probe_iters,
                table_accesses: table.stats.table_accesses - before.table_accesses,
                mod_ops: table.stats.mod_ops - before.mod_ops,
            };
            stats.add(&delta);

            // per-row device work
            let a_nnz = a.row_nnz(r) as u64;
            let b_elems: u64 =
                a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
            let nprod = b_elems;
            let out_bytes = row_nnz as u64 * 12;
            let w = if global_table {
                BlockWork {
                    // every table access is global traffic (12B slots)
                    global_bytes: a_nnz * 20
                        + (b_elems as f64 * 12.0 * b_reuse) as u64
                        + out_bytes
                        + t_size as u64 * 12 // init
                        + delta.table_accesses * 12,
                    shared_accesses: 4 + sort_accesses(row_nnz as u64),
                    global_atomics: 0,
                    mod_ops: delta.mod_ops,
                    flops: 2 * nprod,
                }
            } else {
                // coalesced vectorized memset: 1/8 of a probe access per word
                let init_words = (t_size * NUM_SLOT_BYTES / 4 / 8) as u64 + 1;
                // warp-divergence amplification of collision chains (see
                // symbolic::row_block_work)
                let collision_excess = delta.probe_iters - delta.inserts;
                BlockWork {
                    global_bytes: a_nnz * 20
                        + (b_elems as f64 * 12.0 * b_reuse) as u64
                        + out_bytes,
                    shared_accesses: init_words
                        + delta.table_accesses
                        + 3 * collision_excess
                        + row_nnz as u64 * 3 // condense gather
                        + sort_accesses(row_nnz as u64),
                    global_atomics: 0,
                    mod_ops: delta.mod_ops,
                    flops: 2 * nprod,
                }
            };
            if cfg.rows_per_block > 1 {
                group.add(&w);
                in_group += 1;
                if in_group == cfg.rows_per_block {
                    blocks.push(group);
                    group = BlockWork::default();
                    in_group = 0;
                }
            } else {
                blocks.push(w);
            }
        }
        if in_group > 0 {
            blocks.push(group);
        }
        kernels.push(Kernel {
            name: if cfg.global_table {
                "num_kernel7_global".into()
            } else {
                format!("num_kernel{}", cfg.index)
            },
            step,
            stream: {
                stream = (stream + 1) % num_streams.max(1);
                stream
            },
            tb_size: cfg.tb_size,
            shared_bytes: cfg.shared_bytes,
            blocks,
        });
    }

    let c = Csr {
        rows: a.rows,
        cols: b.cols,
        rpt: c_rpt.to_vec(),
        col: c_col,
        val: c_val,
    };
    NumericOutput { c, stats, kernels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::powerlaw::PowerLaw;
    use crate::gen::uniform::Uniform;
    use crate::sparse::stats::nprod_per_row;
    use crate::spgemm::binning::bin_rows;
    use crate::spgemm::kernel_tables::{NumericRanges, SymbolicRanges};
    use crate::spgemm::reference::spgemm_reference;
    use crate::spgemm::symbolic::symbolic_step;
    use crate::util::exclusive_sum;
    use crate::util::rng::Rng;

    fn full_two_phase(a: &Csr, variant: HashVariant, nr: NumericRanges) -> NumericOutput {
        let nprod = nprod_per_row(a, a);
        let sym_bins = bin_rows(&nprod, &SymbolicRanges::Sym12x.ranges());
        let sym = symbolic_step(a, a, &sym_bins, variant, "symbolic", 4);
        let c_rpt = exclusive_sum(&sym.row_nnz);
        let num_bins = bin_rows(&sym.row_nnz, &nr.ranges());
        numeric_step(a, a, &c_rpt, &num_bins, variant, "numeric", 4)
    }

    #[test]
    fn matches_reference_on_random() {
        let mut rng = Rng::new(91);
        let a = Uniform { n: 250, per_row: 10, jitter: 5 }.generate(&mut rng);
        let out = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num2x);
        let gold = spgemm_reference(&a, &a);
        out.c.validate().unwrap();
        assert!(out.c.approx_eq(&gold, 1e-12), "{:?}", out.c.diff(&gold, 1e-12));
    }

    #[test]
    fn all_numeric_ranges_agree() {
        let mut rng = Rng::new(92);
        let a = Uniform { n: 180, per_row: 14, jitter: 7 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for nr in NumericRanges::all() {
            let out = full_two_phase(&a, HashVariant::SingleAccess, nr);
            assert!(out.c.approx_eq(&gold, 1e-12), "range {:?}", nr);
        }
    }

    #[test]
    fn giant_row_goes_to_global_kernel_and_is_correct() {
        let mut rng = Rng::new(93);
        // the giant row's output nnz must exceed num_2x's last range
        // boundary (4096) to reach the global kernel7
        let a = PowerLaw {
            n: 12_000,
            alpha: 2.0,
            max_row: 8_000,
            mean_row: 4.0,
            hub_frac: 0.3,
            forced_giant_rows: 1,
        }
        .generate(&mut rng);
        let out = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num2x);
        let gold = spgemm_reference(&a, &a);
        assert!(out.c.approx_eq(&gold, 1e-12), "{:?}", out.c.diff(&gold, 1e-12));
        assert!(
            out.kernels.iter().any(|k| k.name == "num_kernel7_global"),
            "giant row should hit the global kernel"
        );
        // §5.5: the global kernel must be launched first
        assert_eq!(out.kernels[0].name, "num_kernel7_global");
    }

    #[test]
    fn multi_access_same_result_more_traffic() {
        let mut rng = Rng::new(94);
        let a = Uniform { n: 150, per_row: 12, jitter: 4 }.generate(&mut rng);
        let s = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num2x);
        let m = full_two_phase(&a, HashVariant::MultiAccess, NumericRanges::Num2x);
        assert!(s.c.approx_eq(&m.c, 1e-12));
        assert!(m.stats.table_accesses > s.stats.table_accesses);
    }

    #[test]
    fn tighter_ranges_reduce_collisions() {
        // num_2x leaves tables at most half full => fewer probe iterations
        // than num_1x, which fills them completely (the Fig 11 mechanism)
        let mut rng = Rng::new(95);
        let a = Uniform { n: 400, per_row: 18, jitter: 9 }.generate(&mut rng);
        let loose = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num1x);
        let tight = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num2x);
        assert!(
            tight.stats.collision_rate() <= loose.stats.collision_rate(),
            "num_2x collisions {} should not exceed num_1x {}",
            tight.stats.collision_rate(),
            loose.stats.collision_rate()
        );
    }

    #[test]
    fn flops_counted() {
        let mut rng = Rng::new(96);
        let a = Uniform { n: 100, per_row: 8, jitter: 3 }.generate(&mut rng);
        let out = full_two_phase(&a, HashVariant::SingleAccess, NumericRanges::Num2x);
        let total: u64 = out.kernels.iter().map(|k| k.total_work().flops).sum();
        let nprod: usize = nprod_per_row(&a, &a).iter().sum();
        assert_eq!(total, 2 * nprod as u64);
    }
}
