//! `cargo bench --bench corpus` — the real-matrix Matrix Market corpus
//! through the full stack: every checked-in `.mtx` fixture plus the
//! synthesized large regimes runs pipeline (reference-verified), the
//! `cusparse_like` baseline, the corpus router, sharded execution, and
//! the serve front door, recording per-matrix speedup, route, bin-range
//! occupancy, and makespan.
//!
//! Env:
//! * `OPSPARSE_CORPUS_DIR=<dir>` — fixture directory (default: first of
//!   `corpus/`, `rust/corpus/`, `../corpus/` that exists)
//! * `OPSPARSE_BENCH_JSON_CORPUS=<path>` — record the report as JSON; CI
//!   writes `BENCH_corpus.json` this way and blocks on: at least
//!   `MIN_REAL_FIXTURES` checked-in fixtures, every matrix bit-identical
//!   across the unsharded/sharded/serve paths, an mmio round trip and a
//!   finite positive speedup per matrix.
//!
//! The bench itself enforces the same contracts, so a plain
//! `cargo bench --bench corpus` fails loudly without CI.

use opsparse::bench::{corpus, write_corpus_json};

fn main() {
    let dir = corpus::resolve_corpus_dir(None);
    println!("corpus bench: loading .mtx fixtures from {}", dir.display());
    let report = corpus::run_corpus(&dir).expect("corpus bench");
    for r in &report.rows {
        println!(
            "  {:<22} {:<11} {:>10} speedup {:>6.2}x gflops {:>7.2} shard {} serve {} mmio {}",
            r.name,
            r.source,
            r.route,
            r.speedup_vs_cusparse,
            r.gflops,
            r.bit_identical_sharded,
            r.bit_identical_serve,
            r.mmio_roundtrip
        );
    }
    println!(
        "corpus: {} fixtures + {} synthesized, all_bit_identical {}",
        report.fixtures, report.synthesized, report.all_bit_identical
    );
    assert!(
        report.fixtures >= corpus::MIN_REAL_FIXTURES,
        "corpus has {} checked-in fixtures, need at least {}",
        report.fixtures,
        corpus::MIN_REAL_FIXTURES
    );
    assert!(
        report.all_bit_identical,
        "a corpus matrix diverged across the unsharded/sharded/serve/mmio paths"
    );
    for r in &report.rows {
        assert!(
            r.speedup_vs_cusparse.is_finite() && r.speedup_vs_cusparse > 0.0,
            "{}: degenerate speedup {}",
            r.name,
            r.speedup_vs_cusparse
        );
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_CORPUS") {
        write_corpus_json(&path, &report).expect("write corpus json");
    }
}
