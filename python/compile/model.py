"""L2 JAX model: the numeric-phase compute graphs the Rust runtime
executes, built on the L1 Pallas kernels.

Python runs only at build time (``make artifacts``); the Rust coordinator
loads the lowered HLO through PJRT and never imports Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.block_matmul import block_pair_matmul, row_window_accumulate

jax.config.update("jax_enable_x64", True)


def block_engine_model(a_blocks: jax.Array, b_blocks: jax.Array) -> tuple[jax.Array]:
    """BSR numeric phase for one batch of block pairs.

    ``(P, T, T) x (P, T, T) -> (P, T, T)`` products; the Rust block engine
    scatters them into the output BSR blocks (segment accumulation happens
    on the Rust side where the segment ids live).

    Returned as a 1-tuple: the AOT bridge lowers with ``return_tuple=True``
    and the Rust side unwraps with ``to_tuple1`` (see aot_recipe).
    """
    return (block_pair_matmul(a_blocks, b_blocks, interpret=True),)


def row_window_model(a_vals: jax.Array, b_rows: jax.Array) -> tuple[jax.Array]:
    """Dense-accumulator numeric phase for one padded row window batch.

    ``(R, K) x (R, K, W) -> (R, W)`` dense output rows.
    """
    return (row_window_accumulate(a_vals, b_rows, interpret=True),)


def block_engine_specs(p: int, t: int, dtype=jnp.float64):
    """ShapeDtypeStructs for lowering ``block_engine_model``."""
    s = jax.ShapeDtypeStruct((p, t, t), dtype)
    return (s, s)

def row_window_specs(r: int, k: int, w: int, dtype=jnp.float64):
    """ShapeDtypeStructs for lowering ``row_window_model``."""
    return (
        jax.ShapeDtypeStruct((r, k), dtype),
        jax.ShapeDtypeStruct((r, k, w), dtype),
    )
