//! `cargo bench --bench engines` — the engine-dispatch ablation:
//! fixed-hash vs fixed-block vs measured dispatch (`EngineMode::Auto`)
//! over blocky/FEM and scattered corpus classes, with per-seed dispatch
//! lifecycles (cold estimate → engine-tagged measurement → hysteresis
//! convergence) and Welch-gated verdicts.
//!
//! Env:
//! * `OPSPARSE_ENGINE_BENCH_REPS=<n>` — seeds per class (default
//!   `DEFAULT_ENGINE_REPS`)
//! * `OPSPARSE_BENCH_JSON_ENGINES=<path>` — record the report as JSON;
//!   CI writes `BENCH_engines.json` this way and blocks on the embedded
//!   gates: per class dispatched is statistically no worse (alpha 0.01)
//!   than the better fixed engine, and on the blocky/FEM classes
//!   dispatched is strictly faster than fixed hash.
//!
//! The bench itself enforces the same contracts, so a plain
//! `cargo bench --bench engines` fails loudly without CI.

use opsparse::bench::{engines, write_engines_json};

fn main() {
    let reps = std::env::var("OPSPARSE_ENGINE_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(engines::DEFAULT_ENGINE_REPS);
    let report = engines::engines_ablation(reps).expect("engines bench");
    println!(
        "{:<20} {:>6} {:>14} {:>14} {:>14} {:>6} {:>5} {:>5}",
        "class", "blocky", "hash_ns", "block_ns", "dispatched_ns", "bpick", "cold", "bit"
    );
    for r in &report.rows {
        println!(
            "{:<20} {:>6} {:>14.0} {:>14.0} {:>14.0} {:>4}/{} {:>3}/{} {:>5}",
            r.class,
            r.blocky,
            r.hash_ns_mean,
            r.block_ns_mean,
            r.dispatched_ns_mean,
            r.dispatched_block_picks,
            r.reps,
            r.cold_agreed,
            r.reps,
            r.bit_identical
        );
    }
    for g in &report.gates {
        println!(
            "gate {:<45} pass {} p {:.4} (candidate {:.0} ns vs reference {:.0} ns)",
            g.name, g.pass, g.p, g.candidate_mean, g.reference_mean
        );
    }
    assert!(
        report.all_bit_identical,
        "the native block engine diverged from the hash pipeline on some seed"
    );
    for g in &report.gates {
        assert!(g.pass, "engine gate {} failed: p={} detail={}", g.name, g.p, g.detail);
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_ENGINES") {
        write_engines_json(&path, &report).expect("write engines json");
    }
}
