//! Symbolic step (paper §5.6.1, Algorithm 4): compute each output row's
//! nnz with per-bin hash kernels. Multiplication is avoided — only the
//! index structure of A and B is touched.
//!
//! Rows binned by `n_prod` are computed by kernel0–kernel7 with
//! shared-memory hash tables; rows whose *actual* distinct-column count
//! exceeds `0.8 ×` kernel7's table are recorded and recomputed by kernel8
//! with a global-memory table.

use super::binning::BinningResult;
use super::hash_table::{HashAccumulator, ProbeStats};
use super::kernel_tables::{
    symbolic_kernels, KernelConfig, SYMBOLIC_GLOBAL_FALLBACK_FRACTION, SYM_SLOT_BYTES,
};
use super::HashVariant;
use crate::gpusim::trace::{BlockWork, Kernel};
use crate::sparse::Csr;

/// Result of the symbolic step.
#[derive(Clone, Debug)]
pub struct SymbolicOutput {
    /// Per-row nnz of C (the paper stores this in the reused `C.rpt`).
    pub row_nnz: Vec<usize>,
    /// Rows recomputed by the global-table kernel8.
    pub fallback_rows: Vec<u32>,
    /// Aggregate probe statistics (Fig 9 metric).
    pub stats: ProbeStats,
    /// Per-bin kernels ready to append to a trace (kernel8 last).
    pub kernels: Vec<Kernel>,
}

/// Per-row work counters for one symbolic row computation. `b_reuse`
/// discounts B-row traffic for L2 reuse (rows of B are re-read by many
/// rows of A when the compression ratio is high).
fn row_block_work(
    a: &Csr,
    b: &Csr,
    row: usize,
    table_init_words: u64,
    stats_delta: &ProbeStats,
    b_reuse: f64,
) -> BlockWork {
    // global traffic: A row columns, B row-pointer pairs + B row columns,
    // one 4-byte nnz write
    let a_nnz = a.row_nnz(row) as u64;
    let b_cols: u64 = a.row_cols(row).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
    // hash collisions serialize at warp granularity (the whole warp spins
    // until its slowest lane exits the probe loop): charge the collision
    // excess at 3x extra on top of the smooth access cost
    let collision_excess = stats_delta.probe_iters - stats_delta.inserts;
    BlockWork {
        global_bytes: a_nnz * 4 + a_nnz * 8 + (b_cols as f64 * 4.0 * b_reuse) as u64 + 4,
        shared_accesses: table_init_words + stats_delta.table_accesses + 3 * collision_excess,
        global_atomics: 0,
        mod_ops: stats_delta.mod_ops,
        flops: 0,
    }
}

/// Compute the symbolic step for all bins.
///
/// `binning` must be over `n_prod` with the symbolic ranges. Returns the
/// per-row nnz plus the kernels (with measured per-block work) in the
/// paper's launch order: **largest bins first** (§5.5), kernel8 last
/// after its table malloc.
pub fn symbolic_step(
    a: &Csr,
    b: &Csr,
    binning: &BinningResult,
    variant: HashVariant,
    step: &'static str,
    num_streams: usize,
) -> SymbolicOutput {
    // L2 reuse factor: effective fraction of B-row traffic that misses
    // cache, estimated from the global reuse ratio nnz(B)/n_prod.
    let nprod_total: usize = (0..a.rows)
        .map(|i| a.row_cols(i).iter().map(|&k| b.row_nnz(k as usize)).sum::<usize>())
        .sum();
    let b_reuse = (b.nnz() as f64 / nprod_total.max(1) as f64).clamp(0.15, 1.0);
    let configs = symbolic_kernels();
    let mut row_nnz = vec![0usize; a.rows];
    let mut fallback_rows: Vec<u32> = Vec::new();
    let mut stats = ProbeStats::default();
    let mut kernels: Vec<Kernel> = Vec::new();

    // launch order: large bins first (bin7 .. bin0), global fallback last
    let bin_order: Vec<usize> = (0..super::kernel_tables::NUM_BINS).rev().collect();
    let mut stream = 0usize;
    let fallback_threshold =
        (configs[7].table_size.unwrap() as f64 * SYMBOLIC_GLOBAL_FALLBACK_FRACTION) as usize;

    for &bin in &bin_order {
        let rows = binning.bin_rows(bin);
        if rows.is_empty() {
            continue;
        }
        let cfg: &KernelConfig = &configs[bin.min(7)];
        let t_size = cfg.table_size.unwrap();
        // table init is a coalesced, conflict-free, vectorized memset:
        // charge it at 1/8 the cost of a random probe access
        let init_words = (t_size * SYM_SLOT_BYTES / 4 / 8) as u64 + 1;
        let mut table = HashAccumulator::new(t_size, variant);
        let mut blocks: Vec<BlockWork> = Vec::with_capacity(rows.len() / cfg.rows_per_block + 1);
        let mut group = BlockWork::default();
        let mut in_group = 0usize;
        for &r in rows {
            let r = r as usize;
            table.reset();
            let before = table.stats;
            let mut nnz = 0usize;
            let mut overflow = bin == 7 && a.row_nnz(r) > 0; // candidate only in bin7
            let mut exceeded = false;
            'outer: for &k in a.row_cols(r) {
                for &c in b.row_cols(k as usize) {
                    match table.insert_symbolic(c) {
                        Some(true) => {
                            nnz += 1;
                            if bin == 7 && nnz > fallback_threshold {
                                exceeded = true;
                                break 'outer;
                            }
                        }
                        Some(false) => {}
                        None => {
                            exceeded = true;
                            break 'outer;
                        }
                    }
                }
            }
            overflow = overflow && exceeded;
            let delta = ProbeStats {
                inserts: table.stats.inserts - before.inserts,
                probe_iters: table.stats.probe_iters - before.probe_iters,
                table_accesses: table.stats.table_accesses - before.table_accesses,
                mod_ops: table.stats.mod_ops - before.mod_ops,
            };
            let w = row_block_work(a, b, r, init_words, &delta, b_reuse);
            if overflow {
                fallback_rows.push(r as u32);
                // the aborted attempt still cost its probes
            } else {
                row_nnz[r] = nnz;
            }
            if cfg.rows_per_block > 1 {
                group.add(&w);
                in_group += 1;
                if in_group == cfg.rows_per_block {
                    blocks.push(group);
                    group = BlockWork::default();
                    in_group = 0;
                }
            } else {
                blocks.push(w);
            }
        }
        if in_group > 0 {
            blocks.push(group);
        }
        stats.add(&table.stats);
        kernels.push(Kernel {
            name: format!("sym_kernel{}", cfg.index),
            step,
            stream: {
                stream = (stream + 1) % num_streams.max(1);
                stream
            },
            tb_size: cfg.tb_size,
            shared_bytes: cfg.shared_bytes,
            blocks,
        });
    }

    // kernel8: global-table recompute of overflowed rows
    if !fallback_rows.is_empty() {
        let cfg = &configs[8];
        let mut blocks = Vec::with_capacity(fallback_rows.len());
        for &r in &fallback_rows {
            let r = r as usize;
            // global table sized to next power of two above n_prod
            let nprod: usize = a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize)).sum();
            let t_size = nprod.next_power_of_two().max(1024) * 2;
            let mut table = HashAccumulator::new(t_size, variant);
            let mut nnz = 0usize;
            for &k in a.row_cols(r) {
                for &c in b.row_cols(k as usize) {
                    if table.insert_symbolic(c).expect("global table overflow") {
                        nnz += 1;
                    }
                }
            }
            row_nnz[r] = nnz;
            // the table lives in *global* memory: every probe is global
            // traffic (4 bytes/access), plus the init memset
            let a_nnz = a.row_nnz(r) as u64;
            let b_cols: u64 = a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
            blocks.push(BlockWork {
                global_bytes: a_nnz * 12
                    + (b_cols as f64 * 4.0 * b_reuse) as u64
                    + 4
                    + t_size as u64 * 4 // init
                    + table.stats.table_accesses * 4,
                shared_accesses: 1,
                global_atomics: 0,
                mod_ops: table.stats.mod_ops,
                flops: 0,
            });
            stats.add(&table.stats);
        }
        kernels.push(Kernel {
            name: "sym_kernel8_global".into(),
            step,
            stream: 0,
            tb_size: cfg.tb_size,
            shared_bytes: cfg.shared_bytes,
            blocks,
        });
    }

    SymbolicOutput { row_nnz, fallback_rows, stats, kernels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::sparse::stats::nprod_per_row;
    use crate::spgemm::binning::bin_rows;
    use crate::spgemm::kernel_tables::SymbolicRanges;
    use crate::spgemm::reference::symbolic_reference;
    use crate::util::rng::Rng;

    fn run(a: &Csr, variant: HashVariant, ranges: SymbolicRanges) -> SymbolicOutput {
        let nprod = nprod_per_row(a, a);
        let binning = bin_rows(&nprod, &ranges.ranges());
        symbolic_step(a, a, &binning, variant, "symbolic", 4)
    }

    #[test]
    fn matches_reference_on_random() {
        let mut rng = Rng::new(77);
        let a = Uniform { n: 300, per_row: 12, jitter: 6 }.generate(&mut rng);
        let out = run(&a, HashVariant::SingleAccess, SymbolicRanges::Sym12x);
        assert_eq!(out.row_nnz, symbolic_reference(&a, &a));
    }

    #[test]
    fn variants_agree_semantically() {
        let mut rng = Rng::new(78);
        let a = Uniform { n: 200, per_row: 10, jitter: 5 }.generate(&mut rng);
        let s = run(&a, HashVariant::SingleAccess, SymbolicRanges::Sym12x);
        let m = run(&a, HashVariant::MultiAccess, SymbolicRanges::Sym12x);
        assert_eq!(s.row_nnz, m.row_nnz);
        assert!(m.stats.table_accesses > s.stats.table_accesses);
    }

    #[test]
    fn all_range_presets_agree() {
        let mut rng = Rng::new(79);
        let a = Uniform { n: 150, per_row: 20, jitter: 10 }.generate(&mut rng);
        let gold = symbolic_reference(&a, &a);
        for r in SymbolicRanges::all() {
            assert_eq!(run(&a, HashVariant::SingleAccess, r).row_nnz, gold, "{:?}", r);
        }
    }

    #[test]
    fn dense_rows_take_global_fallback() {
        // one row of A references many B rows with wide fanout so its
        // output exceeds kernel7's 0.8 threshold => kernel8 path
        let n = 30_000usize;
        let mut rpt = vec![0usize; n + 1];
        let mut col = Vec::new();
        let mut val = Vec::new();
        // row 0: points at 25_000 distinct columns
        for c in 0..25_000u32 {
            col.push(c);
            val.push(1.0);
        }
        rpt[1] = col.len();
        // remaining rows: 1 diagonal entry
        for r in 1..n {
            col.push(r as u32);
            val.push(1.0);
            rpt[r + 1] = col.len();
        }
        let a = Csr::from_parts(n, n, rpt, col, val).unwrap();
        let out = run(&a, HashVariant::SingleAccess, SymbolicRanges::Sym12x);
        assert!(
            out.fallback_rows.contains(&0),
            "row 0 (nnz 25000 > 0.8*24575) must fall back, got {:?}",
            &out.fallback_rows
        );
        assert_eq!(out.row_nnz, symbolic_reference(&a, &a));
        assert!(out.kernels.iter().any(|k| k.name == "sym_kernel8_global"));
    }

    #[test]
    fn kernels_cover_all_nonempty_bins_large_first() {
        let mut rng = Rng::new(80);
        let a = Uniform { n: 400, per_row: 15, jitter: 10 }.generate(&mut rng);
        let out = run(&a, HashVariant::SingleAccess, SymbolicRanges::Sym12x);
        assert!(!out.kernels.is_empty());
        // kernel indices should be non-increasing (large bins first)
        let idx: Vec<usize> = out
            .kernels
            .iter()
            .filter(|k| k.name.starts_with("sym_kernel") && !k.name.contains("global"))
            .map(|k| k.name[10..].parse::<usize>().unwrap())
            .collect();
        let mut sorted = idx.clone();
        sorted.sort_unstable_by(|x, y| y.cmp(x));
        assert_eq!(idx, sorted, "kernels must be emitted largest-bin first");
    }

    #[test]
    fn kernel0_groups_rows_per_block() {
        // all-tiny matrix => bin0 only; blocks = ceil(rows / 256)
        let a = Csr::identity(1000);
        let out = run(&a, HashVariant::SingleAccess, SymbolicRanges::Sym12x);
        let k0 = out.kernels.iter().find(|k| k.name == "sym_kernel0").unwrap();
        assert_eq!(k0.blocks.len(), 1000usize.div_ceil(256));
    }
}
