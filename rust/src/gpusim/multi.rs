//! Multi-device view: aggregate per-device timelines into makespan and
//! scaling figures.
//!
//! A sharded SpGEMM run produces one [`Trace`] per simulated device (see
//! [`crate::spgemm::sharded`]). The devices execute concurrently — each
//! has its own host thread, streams, and SMs — so the end-to-end figure
//! is the **makespan**: the critical path, i.e. the slowest device's
//! wall time. [`MultiDevice`] simulates every trace independently against
//! one [`DeviceParams`] model and reports makespan, per-device times,
//! load imbalance, and scaling efficiency versus a single-device run.
//!
//! Inter-device transfer costs (broadcasting `B`, gathering the stitched
//! `C`) are not yet modeled; see ROADMAP "Open items".

use super::device::DeviceParams;
use super::scheduler::simulate;
use super::timeline::Timeline;
use super::trace::Trace;

/// Per-device simulation results of one multi-device run.
#[derive(Clone, Debug, Default)]
pub struct MultiDevice {
    /// One timeline per device, in device order.
    pub timelines: Vec<Timeline>,
}

impl MultiDevice {
    /// Simulate one trace per device against the same device model.
    pub fn simulate<'a, I>(traces: I, dev: &DeviceParams) -> MultiDevice
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        MultiDevice { timelines: traces.into_iter().map(|t| simulate(t, dev)).collect() }
    }

    pub fn n_devices(&self) -> usize {
        self.timelines.len()
    }

    /// Critical path: the slowest device's wall time (devices run
    /// concurrently).
    pub fn makespan_ns(&self) -> f64 {
        self.timelines.iter().map(|t| t.total_ns).fold(0.0, f64::max)
    }

    /// Per-device wall times in device order.
    pub fn device_total_ns(&self) -> Vec<f64> {
        self.timelines.iter().map(|t| t.total_ns).collect()
    }

    /// Measured load imbalance: max device wall time / mean device wall
    /// time (1.0 = perfect; idle devices count toward the mean).
    pub fn time_imbalance(&self) -> f64 {
        if self.timelines.is_empty() {
            return 1.0;
        }
        let mean: f64 =
            self.timelines.iter().map(|t| t.total_ns).sum::<f64>() / self.timelines.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.makespan_ns() / mean
        }
    }

    /// Speedup over a single-device wall time.
    pub fn speedup_vs(&self, single_device_ns: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            single_device_ns / m
        }
    }

    /// Scaling efficiency: speedup divided by device count (1.0 = linear).
    pub fn efficiency_vs(&self, single_device_ns: f64) -> f64 {
        if self.timelines.is_empty() {
            return 0.0;
        }
        self.speedup_vs(single_device_ns) / self.timelines.len() as f64
    }

    /// GFLOPS under the makespan (the paper's metric over the fleet).
    pub fn gflops(&self, flops: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            flops / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;
    use crate::gpusim::trace::{BlockWork, Kernel};

    fn trace_with_blocks(nblocks: usize) -> Trace {
        let mut t = Trace::new();
        t.launch(Kernel {
            name: "k".into(),
            step: "numeric",
            stream: 0,
            tb_size: 256,
            shared_bytes: 0,
            blocks: vec![BlockWork { global_bytes: 100_000, ..Default::default() }; nblocks],
        });
        t
    }

    #[test]
    fn makespan_is_slowest_device() {
        let fast = trace_with_blocks(10);
        let slow = trace_with_blocks(4000);
        let md = MultiDevice::simulate([&fast, &slow], &V100);
        assert_eq!(md.n_devices(), 2);
        let per = md.device_total_ns();
        assert!((md.makespan_ns() - per[1]).abs() < 1e-6);
        assert!(per[1] > per[0]);
        assert!(md.time_imbalance() > 1.0);
    }

    #[test]
    fn balanced_devices_have_low_imbalance_and_good_efficiency() {
        let traces: Vec<Trace> = (0..4).map(|_| trace_with_blocks(1000)).collect();
        let md = MultiDevice::simulate(traces.iter(), &V100);
        assert!((md.time_imbalance() - 1.0).abs() < 1e-9);
        let single = simulate(&trace_with_blocks(4000), &V100).total_ns;
        let eff = md.efficiency_vs(single);
        assert!(eff > 0.5, "4-way split of a 4x trace should scale: eff={eff}");
    }

    #[test]
    fn empty_fleet_is_degenerate_but_defined() {
        let md = MultiDevice::default();
        assert_eq!(md.makespan_ns(), 0.0);
        assert_eq!(md.time_imbalance(), 1.0);
        assert_eq!(md.efficiency_vs(1.0), 0.0);
    }
}
