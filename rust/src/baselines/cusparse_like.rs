//! cuSPARSE-like baseline (paper §3): two-phase SpGEMM with the **naive
//! load balance** — every output row is computed by the *same* kernel
//! regardless of its `n_prod`/`n_nz`, with a fixed-size shared-memory hash
//! table and a global-memory fallback that **recomputes** the row from
//! scratch when the shared table overflows.
//!
//! The paper's observations reproduced here:
//! * one kernel per phase → severe SM load imbalance on skewed matrices
//!   (a giant row and a 1-nnz row get the same thread block);
//! * overflowing rows are computed twice (shared attempt + global redo);
//! * the kernel reserves shared memory for its table even for rows that
//!   would not need it, capping occupancy.

use crate::gpusim::trace::{BlockWork, Kernel, Trace};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use crate::spgemm::hash_table::{HashAccumulator, ProbeStats};
use crate::spgemm::pipeline::SpgemmOutput;
use crate::spgemm::HashVariant;
use crate::util::exclusive_sum;
use anyhow::{ensure, Result};

/// Fixed shared-table sizes of the single symbolic / numeric kernels.
const SYM_TABLE: usize = 2048; // 8 KB of 4-byte keys
const NUM_TABLE: usize = 1024; // 12 KB of key+value slots
const TB: usize = 128;

struct PhaseResult {
    row_sizes: Vec<usize>,
    kernels: Vec<Kernel>,
    stats: ProbeStats,
    global_table_bytes: usize,
    /// Numeric phase only: the assembled C arrays.
    c_col: Vec<u32>,
    c_val: Vec<f64>,
}

/// One phase (symbolic if `c_rpt` is None, numeric otherwise).
fn phase(a: &Csr, b: &Csr, c_rpt: Option<&[usize]>, step: &'static str) -> PhaseResult {
    let numeric = c_rpt.is_some();
    // L2 reuse discount on B-row traffic (same model as the binned
    // pipelines, for a fair comparison)
    let nprod_total: usize = nprod_per_row(a, b).iter().sum();
    let b_reuse = (b.nnz() as f64 / nprod_total.max(1) as f64).clamp(0.15, 1.0);
    let t_size = if numeric { NUM_TABLE } else { SYM_TABLE };
    let mut stats = ProbeStats::default();
    let mut row_sizes = vec![0usize; a.rows];
    let mut overflow_rows: Vec<u32> = Vec::new();
    let mut main_blocks: Vec<BlockWork> = Vec::with_capacity(a.rows);
    let nnz_total = c_rpt.map(|r| *r.last().unwrap()).unwrap_or(0);
    let mut c_col = vec![0u32; nnz_total];
    let mut c_val = vec![0f64; nnz_total];
    let mut row_cols: Vec<u32> = Vec::new();
    let mut row_vals: Vec<f64> = Vec::new();

    // ---- main kernel: one (identical) thread block per row ----
    let mut table = HashAccumulator::new(t_size, HashVariant::MultiAccess);
    for r in 0..a.rows {
        table.reset();
        let before = table.stats;
        let (acols, avals) = a.row(r);
        let mut nnz = 0usize;
        let mut overflowed = false;
        'row: for (&k, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(k as usize);
            for (&c, &bv) in bcols.iter().zip(bvals) {
                if numeric {
                    if !table.insert_numeric(c, av * bv) {
                        overflowed = true;
                        break 'row;
                    }
                } else {
                    match table.insert_symbolic(c) {
                        Some(true) => nnz += 1,
                        Some(false) => {}
                        None => {
                            overflowed = true;
                            break 'row;
                        }
                    }
                }
            }
        }
        let delta_access = table.stats.table_accesses - before.table_accesses;
        let collision_excess = (table.stats.probe_iters - before.probe_iters)
            - (table.stats.inserts - before.inserts);
        let a_nnz = a.row_nnz(r) as u64;
        let b_elems: u64 = a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
        let elem_bytes: u64 = if numeric { 12 } else { 4 };
        main_blocks.push(BlockWork {
            global_bytes: a_nnz * (4 + elem_bytes)
                + (b_elems as f64 * elem_bytes as f64 * b_reuse) as u64
                + 4,
            shared_accesses: (t_size as u64 * elem_bytes / 4 / 8) + delta_access + 3 * collision_excess,
            global_atomics: 0,
            mod_ops: 0,
            flops: if numeric { 2 * b_elems } else { 0 },
        });
        if overflowed {
            overflow_rows.push(r as u32);
        } else if numeric {
            row_cols.clear();
            row_vals.clear();
            table.condense_sorted(&mut row_cols, &mut row_vals);
            let rpt = c_rpt.unwrap();
            c_col[rpt[r]..rpt[r + 1]].copy_from_slice(&row_cols);
            c_val[rpt[r]..rpt[r + 1]].copy_from_slice(&row_vals);
            row_sizes[r] = row_cols.len();
        } else {
            row_sizes[r] = nnz;
        }
    }
    stats.add(&table.stats);
    let mut kernels = vec![Kernel {
        name: format!("cusparse_{step}_main"),
        step,
        stream: 0,
        tb_size: TB,
        shared_bytes: t_size * if numeric { 12 } else { 4 } + 4,
        blocks: main_blocks,
    }];

    // ---- global fallback kernel: recompute overflowed rows ----
    let mut global_table_bytes = 0usize;
    if !overflow_rows.is_empty() {
        let mut blocks = Vec::with_capacity(overflow_rows.len());
        for &r in &overflow_rows {
            let r = r as usize;
            let np: usize = a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize)).sum();
            let gt_size = np.next_power_of_two().max(4096) * 2;
            global_table_bytes += gt_size * if numeric { 12 } else { 4 };
            let mut gt = HashAccumulator::new(gt_size, HashVariant::MultiAccess);
            let (acols, avals) = a.row(r);
            let mut nnz = 0usize;
            for (&k, &av) in acols.iter().zip(avals) {
                let (bcols, bvals) = b.row(k as usize);
                for (&c, &bv) in bcols.iter().zip(bvals) {
                    if numeric {
                        assert!(gt.insert_numeric(c, av * bv), "global table overflow");
                    } else if gt.insert_symbolic(c) == Some(true) {
                        nnz += 1;
                    }
                }
            }
            if numeric {
                row_cols.clear();
                row_vals.clear();
                gt.condense_sorted(&mut row_cols, &mut row_vals);
                let rpt = c_rpt.unwrap();
                c_col[rpt[r]..rpt[r + 1]].copy_from_slice(&row_cols);
                c_val[rpt[r]..rpt[r + 1]].copy_from_slice(&row_vals);
                row_sizes[r] = row_cols.len();
            } else {
                row_sizes[r] = nnz;
            }
            let a_nnz = a.row_nnz(r) as u64;
            let b_elems: u64 =
                a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
            let elem_bytes: u64 = if numeric { 12 } else { 4 };
            blocks.push(BlockWork {
                global_bytes: a_nnz * (4 + elem_bytes)
                    + (b_elems as f64 * elem_bytes as f64 * b_reuse) as u64
                    + gt_size as u64 * elem_bytes
                    + gt.stats.table_accesses * elem_bytes,
                shared_accesses: 1,
                global_atomics: 0,
                mod_ops: 0,
                flops: if numeric { 2 * b_elems } else { 0 },
            });
            stats.add(&gt.stats);
        }
        kernels.push(Kernel {
            name: format!("cusparse_{step}_global_redo"),
            step,
            stream: 0,
            tb_size: TB,
            shared_bytes: 4,
            blocks,
        });
    }

    PhaseResult { row_sizes, kernels, stats, global_table_bytes, c_col, c_val }
}

/// cuSPARSE-like SpGEMM: `C = A * B`.
pub fn multiply_cusparse(a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
    ensure!(a.cols == b.rows, "dimension mismatch");
    let mut trace = Trace::new();
    let nprod_total: usize = nprod_per_row(a, b).iter().sum();

    // setup: C.rpt allocation, no binning metadata
    trace.malloc(4 * (a.rows + 1), "c_rpt", "setup");

    // ---- symbolic phase ----
    let sym = phase(a, b, None, "symbolic");
    if sym.global_table_bytes > 0 {
        trace.malloc(sym.global_table_bytes, "sym_global", "symbolic");
    }
    for k in sym.kernels {
        trace.launch(k);
    }
    let sym_stats = sym.stats;

    // ---- alloc C ----
    let c_rpt = exclusive_sum(&sym.row_sizes);
    let c_nnz = *c_rpt.last().unwrap();
    // cub exclusive-sum over the row sizes (same kernel shape as the
    // binned pipelines)
    trace.launch(Kernel {
        name: "cusparse_exscan".into(),
        step: "alloc_c",
        stream: 0,
        tb_size: 256,
        shared_bytes: 2048,
        blocks: (0..a.rows.div_ceil(2048).max(1))
            .map(|blk| {
                let lo = blk * 2048;
                let rows = 2048.min(a.rows + 1 - lo.min(a.rows + 1));
                BlockWork { global_bytes: rows as u64 * 8, ..Default::default() }
            })
            .collect(),
    });
    trace.memcpy_d2h(8, "alloc_c");
    trace.device_sync("alloc_c");
    trace.malloc(4 * c_nnz, "c_col", "alloc_c");
    trace.malloc(8 * c_nnz, "c_val", "alloc_c");

    // ---- numeric phase ----
    let num = phase(a, b, Some(&c_rpt), "numeric");
    if num.global_table_bytes > 0 {
        trace.malloc(num.global_table_bytes, "num_global", "numeric");
    }
    for k in num.kernels {
        trace.launch(k);
    }

    trace.device_sync("cleanup");
    trace.free("tables", "cleanup");

    let c = Csr { rows: a.rows, cols: b.cols, rpt: c_rpt, col: num.c_col, val: num.c_val };
    Ok(SpgemmOutput {
        c,
        trace,
        nprod: nprod_total,
        sym_stats,
        num_stats: num.stats,
        sym_fallback_rows: 0,
        symbolic_skipped: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::powerlaw::PowerLaw;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn matches_reference() {
        let mut rng = Rng::new(31);
        let a = Uniform { n: 250, per_row: 10, jitter: 5 }.generate(&mut rng);
        let out = multiply_cusparse(&a, &a).unwrap();
        let gold = spgemm_reference(&a, &a);
        assert!(out.c.approx_eq(&gold, 1e-12), "{:?}", out.c.diff(&gold, 1e-12));
    }

    #[test]
    fn overflow_rows_recomputed_globally() {
        let mut rng = Rng::new(32);
        // giant rows overflow the 2048-slot symbolic table
        let a = PowerLaw {
            n: 6000,
            alpha: 2.0,
            max_row: 4000,
            mean_row: 4.0,
            hub_frac: 0.2,
            forced_giant_rows: 1,
        }
        .generate(&mut rng);
        let out = multiply_cusparse(&a, &a).unwrap();
        let gold = spgemm_reference(&a, &a);
        assert!(out.c.approx_eq(&gold, 1e-12));
        // the redo kernel must exist in the trace
        let has_redo = out.trace.ops.iter().any(|op| match op {
            crate::gpusim::trace::TraceOp::Launch(k) => k.name.contains("global_redo"),
            _ => false,
        });
        assert!(has_redo, "expected global recompute kernel");
    }

    #[test]
    fn empty_matrix() {
        let z = Csr::zero(5, 5);
        let out = multiply_cusparse(&z, &z).unwrap();
        assert_eq!(out.c.nnz(), 0);
    }
}
