//! Reassembly barrier for cross-worker shard fan-out.
//!
//! [`crate::coordinator::Coordinator::submit`] splits a
//! [`Route::Sharded`] job into one sub-job per shard and fans them out
//! over the whole hash-worker pool, so one oversized multiply and many
//! small jobs share the fleet instead of the shards being trapped on one
//! worker's scoped threads. Each sub-job reports its `C` row block here;
//! when the last shard lands, the barrier stitches the blocks back in
//! shard order (bit-identical to the in-worker and unsharded paths, via
//! [`stitch_row_blocks`]) and emits **exactly one** [`JobResult`] for
//! the parent job:
//!
//! * all shards `Ok` → the stitched CSR;
//! * any shard `Err` (a failed worker, a poisoned shard caught by the
//!   worker's panic guard) → one failure carrying the first shard error,
//!   after every shard has reported — never a partial stitch;
//! * the barrier dropped with shards still outstanding (queued sub-jobs
//!   discarded because the coordinator was dropped mid-flight) → one
//!   failure from `Drop`, so a lost shard can never hang the parent.
//!
//! A clean [`crate::coordinator::Coordinator::shutdown`] does not hit
//! the `Drop` path: stop markers queue *behind* already-submitted
//! sub-jobs, so workers drain every in-flight barrier first.

use super::cache::PatternKey;
use super::feedback::{Engine, ExecHistory, RunObservation};
use super::metrics::Metrics;
use super::router::Route;
use super::service::{finish, JobResult};
use crate::obs::{Span, Tracer, LANE_FRONT};
use crate::sparse::Csr;
use crate::spgemm::pipeline::SpgemmOutput;
use crate::spgemm::sharded::{stitch_row_blocks, MeasuredShard};
use anyhow::{anyhow, Result};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// What the barrier needs to feed the execution history when the parent
/// completes: the shared store, the pattern key, and the row ranges the
/// plan assigned (shard `s` of the observation is `ranges[s]` plus the
/// measured ns its worker reported). Attached only when adaptive
/// re-planning is on — with it off, the barrier does exactly what it
/// did before.
pub struct ShardFeedback {
    pub history: Arc<Mutex<ExecHistory>>,
    pub key: PatternKey,
    pub ranges: Vec<(usize, usize)>,
}

/// Straggler-speculation knobs. Off by default: with speculation off the
/// barrier (and the whole coordinator) reproduces the pre-speculation
/// baseline exactly — no monitor thread, no extra sub-jobs, identical
/// metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpeculateConfig {
    pub enabled: bool,
    /// Launch a backup for a shard once the parent has been running
    /// `lag_factor ×` the median wall time of its completed shards.
    pub lag_factor: f64,
    /// Never speculate before this much wall time has passed — keeps
    /// microsecond-scale jobs from paying backup overhead.
    pub min_lag_ns: u64,
}

impl Default for SpeculateConfig {
    fn default() -> Self {
        SpeculateConfig { enabled: false, lag_factor: 3.0, min_lag_ns: 200_000 }
    }
}

impl SpeculateConfig {
    pub fn on() -> Self {
        SpeculateConfig { enabled: true, ..Default::default() }
    }
}

/// Everything needed to relaunch one shard speculatively: the shared
/// operands plus the shard-task ingredients the original submit used.
/// Stored on the barrier (not the `ShardTask`s themselves — those hold
/// an `Arc<ShardBarrier>` and storing them here would leak the barrier
/// through an `Arc` cycle).
pub struct SpeculationState {
    pub cfg: SpeculateConfig,
    pub a: Arc<Csr>,
    pub b: Arc<Csr>,
    pub b_fp: u64,
    pub measure: bool,
    pub ranges: Vec<(usize, usize)>,
    /// Engine the primaries run on — a backup must run the identical
    /// engine or first-result-wins would not be bit-identical.
    pub engine: Engine,
    /// Block size of the shard plan's alignment (block-engine shards).
    pub block_t: usize,
}

/// One backup sub-job the speculation monitor should launch.
pub struct SpeculationPlan {
    pub shard: usize,
    pub lo: usize,
    pub hi: usize,
    pub a: Arc<Csr>,
    pub b: Arc<Csr>,
    pub b_fp: u64,
    pub measure: bool,
    pub engine: Engine,
    pub block_t: usize,
}

struct State {
    /// One slot per shard, filled by [`ShardBarrier::complete`].
    slots: Vec<Option<Result<SpgemmOutput>>>,
    /// Measured per-shard execution ns, parallel to `slots`. `None`
    /// when the worker reported no measurement (e.g. a symbolic-cache
    /// replay, whose trace time is not comparable to a cold shard's).
    ns: Vec<Option<f64>>,
    /// Wall ns (from the parent's `t0`) at which each shard's slot was
    /// filled — the timing view straggler detection runs on.
    done_wall_ns: Vec<Option<u64>>,
    /// Outstanding attempt chains per shard: 1 for the primary, +1 when
    /// a speculative backup launches, −1 when a chain is abandoned
    /// (retry budget exhausted). A shard only resolves to an error when
    /// its last chain dies.
    inflight: Vec<usize>,
    /// Whether a backup has already been launched (at most one).
    speculated: Vec<bool>,
    /// First abandonment error per shard, held back while another chain
    /// is still running (that chain may yet deliver the result).
    deferred: Vec<Option<anyhow::Error>>,
    /// Shards still outstanding.
    remaining: usize,
    /// Set once the parent `JobResult` has been emitted.
    finished: bool,
}

/// Collects the per-shard results of one sharded job and emits the
/// parent [`JobResult`] when the last shard reports (or on `Drop`, if
/// the coordinator dies with shards outstanding).
pub struct ShardBarrier {
    job_id: u64,
    route: Route,
    /// Stitched result shape: `rows` = parent `A.rows`, `cols` = `B.cols`.
    rows: usize,
    cols: usize,
    t0: Instant,
    tx: mpsc::Sender<JobResult>,
    metrics: Arc<Metrics>,
    /// Execution-history hook, when adaptive re-planning is on.
    feedback: Option<ShardFeedback>,
    /// Straggler-speculation hook ([`ShardBarrier::set_speculation`]):
    /// operand handles + ranges so the monitor can relaunch a lagging
    /// shard. `None` with speculation off.
    spec: Option<SpeculationState>,
    /// Request tracer ([`ShardBarrier::set_obs`]) — the stitch records
    /// its own span under the parent request. `None` with tracing off.
    tracer: Option<Arc<Tracer>>,
    state: Mutex<State>,
}

impl ShardBarrier {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        job_id: u64,
        route: Route,
        n_shards: usize,
        rows: usize,
        cols: usize,
        tx: mpsc::Sender<JobResult>,
        metrics: Arc<Metrics>,
        t0: Instant,
        feedback: Option<ShardFeedback>,
    ) -> ShardBarrier {
        let n = n_shards.max(1);
        ShardBarrier {
            job_id,
            route,
            rows,
            cols,
            t0,
            tx,
            metrics,
            feedback,
            spec: None,
            tracer: None,
            state: Mutex::new(State {
                slots: (0..n).map(|_| None).collect(),
                ns: vec![None; n],
                done_wall_ns: vec![None; n],
                inflight: vec![1; n],
                speculated: vec![false; n],
                deferred: (0..n).map(|_| None).collect(),
                remaining: n,
                finished: false,
            }),
        }
    }

    /// Attach the speculation hook (called by `submit` before the
    /// barrier is shared, when `--speculate on`). Without it the barrier
    /// never reports stragglers and behaves exactly as before.
    pub fn set_speculation(&mut self, spec: SpeculationState) {
        self.spec = Some(spec);
    }

    /// Attach the request tracer (called by `submit` before the barrier
    /// is shared, when tracing is on). Without it the barrier performs
    /// zero tracing work.
    pub fn set_obs(&mut self, tracer: Arc<Tracer>) {
        self.tracer = Some(tracer);
    }

    /// The parent job's id — also its trace id, so shard workers can
    /// attribute their attempt spans without widening [`super::service`]'s
    /// message types.
    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// Record shard `shard`'s result (plus its measured execution ns,
    /// when the worker timed it). The last arrival stitches and emits
    /// the parent result — and, with a [`ShardFeedback`] attached and a
    /// successful stitch, folds the measured per-shard timings into the
    /// execution history so the *next* submit of this pattern re-cuts
    /// from them. Duplicate or late reports are ignored.
    pub fn complete(&self, shard: usize, result: Result<SpgemmOutput>, measured_ns: Option<f64>) {
        self.complete_from(shard, result, measured_ns, false);
    }

    /// [`ShardBarrier::complete`], tagged with whether the report came
    /// from a speculative backup. **First result wins**: whichever
    /// attempt fills the slot decides the shard (primary and backup
    /// compute the identical deterministic row slice, so the stitched
    /// output is bit-identical either way); the loser's later report
    /// hits the duplicate guard and is discarded.
    pub fn complete_from(
        &self,
        shard: usize,
        result: Result<SpgemmOutput>,
        measured_ns: Option<f64>,
        speculative: bool,
    ) {
        let ready = {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            // defensive: a duplicate, out-of-range, or post-completion
            // report is ignored rather than corrupting the stitch
            if st.finished || shard >= st.slots.len() || st.slots[shard].is_some() {
                return;
            }
            if speculative {
                self.metrics.speculative_wins.fetch_add(1, Ordering::Relaxed);
            }
            st.slots[shard] = Some(result);
            st.ns[shard] = measured_ns;
            st.done_wall_ns[shard] = Some(self.t0.elapsed().as_nanos() as u64);
            st.remaining -= 1;
            if st.remaining == 0 {
                st.finished = true;
                Some((std::mem::take(&mut st.slots), std::mem::take(&mut st.ns)))
            } else {
                None
            }
        };
        // stitch outside the lock: it is O(nnz(C)) of copying
        if let Some((slots, ns)) = ready {
            let n_shards = slots.len();
            let span_t0 = self.tracer.as_ref().map(|t| t.now_ns());
            let (c, nprod) = Self::reassemble(self.rows, self.cols, slots);
            if c.is_ok() {
                self.observe(&ns, nprod);
            }
            // stitch span recorded before `finish` sends the result —
            // the request root (closed by the fan-out that receives it)
            // must still be open so the span nests inside it
            if let (Some(tr), Some(s0)) = (self.tracer.as_ref(), span_t0) {
                let s1 = tr.now_ns();
                let parent = tr.parent_for(self.job_id);
                tr.record(Span {
                    trace: self.job_id,
                    id: tr.next_span_id(),
                    parent,
                    name: "stitch".to_string(),
                    lane: LANE_FRONT,
                    t0_ns: s0,
                    t1_ns: s1,
                    args: vec![("shards".to_string(), n_shards.to_string())],
                    error: c.is_err(),
                    instant: false,
                });
                self.metrics.phases.stitch.observe(s1.saturating_sub(s0));
            }
            finish(&self.metrics, &self.tx, self.job_id, self.route, c, nprod, self.t0);
        }
    }

    /// One attempt chain for `shard` died permanently (its retry budget
    /// is exhausted). If another chain is still in flight (a speculative
    /// backup, or the primary when the backup died), the error is held
    /// back — that chain may yet deliver. Only when the *last* chain
    /// dies does the shard resolve to a clean error, failing the parent
    /// through the normal all-shards-reported path.
    pub fn abandon(&self, shard: usize, err: anyhow::Error) {
        {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            if st.finished || shard >= st.slots.len() || st.slots[shard].is_some() {
                return;
            }
            st.inflight[shard] = st.inflight[shard].saturating_sub(1);
            if st.inflight[shard] > 0 {
                if st.deferred[shard].is_none() {
                    st.deferred[shard] = Some(err);
                }
                return;
            }
            // fall through to complete() with the first chain's error
        }
        let first = {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            st.deferred.get_mut(shard).and_then(|d| d.take())
        };
        self.complete(shard, Err(first.unwrap_or(err)), None);
    }

    /// Speculation monitor entry point: under the barrier's timing view,
    /// return the backup sub-jobs to launch *now*. Requires speculation
    /// attached, a completed-shard quorum (≥ half), and the parent's
    /// wall time exceeding `max(lag_factor × median completed wall,
    /// min_lag_ns)`. Each shard speculates at most once; the returned
    /// plans are already marked in flight, so the caller just launches
    /// them.
    pub fn stragglers(&self) -> Vec<SpeculationPlan> {
        let Some(spec) = &self.spec else { return Vec::new() };
        if !spec.cfg.enabled {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let n = st.slots.len();
        if st.finished || st.remaining == 0 {
            return Vec::new();
        }
        let mut done: Vec<u64> = st.done_wall_ns.iter().flatten().copied().collect();
        // quorum: without a majority of shards done, "the median of
        // completed shards" says nothing about who is lagging
        if done.len() * 2 < n {
            return Vec::new();
        }
        done.sort_unstable();
        let median = done[done.len() / 2] as f64;
        let threshold = (median * spec.cfg.lag_factor).max(spec.cfg.min_lag_ns as f64);
        if (self.t0.elapsed().as_nanos() as f64) < threshold {
            return Vec::new();
        }
        let mut plans = Vec::new();
        for s in 0..n {
            if st.slots[s].is_none() && !st.speculated[s] && st.inflight[s] > 0 {
                st.speculated[s] = true;
                st.inflight[s] += 1;
                let (lo, hi) = spec.ranges[s];
                plans.push(SpeculationPlan {
                    shard: s,
                    lo,
                    hi,
                    a: Arc::clone(&spec.a),
                    b: Arc::clone(&spec.b),
                    b_fp: spec.b_fp,
                    measure: spec.measure,
                    engine: spec.engine,
                    block_t: spec.block_t,
                });
            }
        }
        plans
    }

    /// Fold this run into the execution history (successful parents
    /// only — a failed shard's timings describe nothing worth planning
    /// from) and refresh the occupancy gauges. A run where any shard
    /// reported no measurement (a symbolic-cache replay) is dropped
    /// whole: mixing replayed and cold shard times would hand the
    /// planner incomparable numbers, so only homogeneous all-cold runs
    /// update the plan history — at the cost of staleness for plans
    /// whose shards stay partially cache-warm (see the ROADMAP
    /// re-measurement follow-on).
    fn observe(&self, ns: &[Option<f64>], nprod: usize) {
        let Some(fb) = &self.feedback else { return };
        if ns.iter().any(|n| n.is_none()) {
            return;
        }
        let shards: Vec<MeasuredShard> = fb
            .ranges
            .iter()
            .zip(ns)
            .map(|(&(lo, hi), &ns)| MeasuredShard { lo, hi, ns: ns.unwrap_or(0.0) })
            .collect();
        // Engine-tagged timing: the shards ran in parallel, so the
        // engine-comparable figure is the makespan (slowest shard), not
        // the sum — that is what an unsharded run of the same engine
        // competes against in the dispatcher.
        let engine = match self.route {
            Route::ShardedBlock { .. } | Route::Block => Engine::Block,
            _ => Engine::Hash,
        };
        let engine_ns = shards.iter().map(|s| s.ns).fold(0.0_f64, f64::max);
        let obs = RunObservation {
            shards,
            wall_ns: self.t0.elapsed().as_nanos() as f64,
            nprod: nprod as u64,
            chunk: None,
            engine,
            engine_ns,
        };
        let mut h = fb.history.lock().unwrap_or_else(|e| e.into_inner());
        h.record(fb.key, obs);
        self.metrics.history_patterns.store(h.len() as u64, Ordering::Relaxed);
        self.metrics.history_evictions.store(h.evictions(), Ordering::Relaxed);
    }

    fn reassemble(
        rows: usize,
        cols: usize,
        slots: Vec<Option<Result<SpgemmOutput>>>,
    ) -> (Result<Csr>, usize) {
        let mut shards = Vec::with_capacity(slots.len());
        let mut failure: Option<anyhow::Error> = None;
        for (s, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(out)) => shards.push(out),
                Some(Err(e)) => {
                    if failure.is_none() {
                        failure = Some(e.context(format!("shard {s} failed")));
                    }
                }
                None => {
                    if failure.is_none() {
                        failure = Some(anyhow!("shard {s} never reported"));
                    }
                }
            }
        }
        match failure {
            Some(e) => (Err(e), 0),
            None => match stitch_row_blocks(rows, cols, &shards) {
                Ok((c, nprod)) => (Ok(c), nprod),
                Err(e) => (Err(e), 0),
            },
        }
    }
}

impl Drop for ShardBarrier {
    fn drop(&mut self) {
        let st = self.state.get_mut().unwrap_or_else(|e| e.into_inner());
        if !st.finished {
            st.finished = true;
            let lost = st.remaining;
            let total = st.slots.len();
            finish(
                &self.metrics,
                &self.tx,
                self.job_id,
                self.route,
                Err(anyhow!("coordinator dropped with {lost} of {total} shards in flight")),
                0,
                self.t0,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};

    fn barrier_for(
        n_shards: usize,
        rows: usize,
        cols: usize,
    ) -> (Arc<ShardBarrier>, mpsc::Receiver<JobResult>, Arc<Metrics>) {
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let b = Arc::new(ShardBarrier::new(
            7,
            Route::Sharded { n_devices: n_shards },
            n_shards,
            rows,
            cols,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            None,
        ));
        (b, rx, metrics)
    }

    fn shard_output(m: &Csr) -> SpgemmOutput {
        multiply(m, m, &OpSparseConfig::default()).unwrap()
    }

    #[test]
    fn out_of_order_completion_stitches_in_shard_order() {
        let m = Csr::identity(4);
        let gold = shard_output(&m).c;
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        // two identity blocks, completed in reverse order
        b.complete(1, Ok(shard_output(&m)), None);
        assert!(rx.try_recv().is_err(), "barrier must wait for every shard");
        b.complete(0, Ok(shard_output(&m)), None);
        let r = rx.recv().unwrap();
        let c = r.c.unwrap();
        assert_eq!(c.rows, 8);
        assert_eq!(c.nnz(), 2 * gold.nnz());
        assert_eq!(metrics.snapshot().jobs_completed, 1);
    }

    #[test]
    fn one_failed_shard_fails_the_parent_exactly_once() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(3, 12, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        b.complete(2, Err(anyhow!("injected")), None);
        assert!(rx.try_recv().is_err(), "no partial result before all shards report");
        b.complete(1, Ok(shard_output(&m)), None);
        let r = rx.recv().unwrap();
        assert!(r.c.is_err());
        assert!(rx.try_recv().is_err(), "exactly one JobResult");
        let snap = metrics.snapshot();
        assert_eq!(snap.jobs_failed, 1);
        assert_eq!(snap.jobs_completed, 0);
    }

    #[test]
    fn dropping_an_open_barrier_fails_the_parent() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        drop(b);
        let r = rx.recv().unwrap();
        assert!(r.c.is_err(), "a lost shard must fail the job, not hang it");
        assert_eq!(metrics.snapshot().jobs_failed, 1);
    }

    #[test]
    fn finished_barrier_drop_is_silent() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(1, 4, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        assert!(rx.recv().unwrap().c.is_ok());
        drop(b);
        assert!(rx.try_recv().is_err());
        assert_eq!(metrics.snapshot().jobs_completed, 1);
        assert_eq!(metrics.snapshot().jobs_failed, 0);
    }

    #[test]
    fn successful_parent_records_measured_shards_into_history() {
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            7,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Ok(shard_output(&m)), Some(2500.0));
        assert!(rx.recv().unwrap().c.is_ok());
        let h = history.lock().unwrap();
        let stats = h.lookup((11, 22)).expect("completed parent must record");
        assert_eq!(
            stats.measured,
            vec![
                MeasuredShard { lo: 0, hi: 4, ns: 1500.0 },
                MeasuredShard { lo: 4, hi: 8, ns: 2500.0 }
            ]
        );
        assert!(stats.ewma_wall_ns > 0.0, "end-to-end wall time must be folded in");
        assert!(stats.hash.warm(), "a Sharded (hash-engine) run must tag the hash EWMA");
        assert_eq!(stats.hash.ewma_ns, 2500.0, "engine ns is the shard makespan");
        assert!(!stats.block.warm(), "the block EWMA must stay untouched");
        let snap = metrics.snapshot();
        assert_eq!(snap.history_patterns, 1, "occupancy gauge must refresh");
    }

    #[test]
    fn sharded_block_parent_tags_the_block_engine() {
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            7,
            Route::ShardedBlock { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (33, 44),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(900.0));
        b.complete(1, Ok(shard_output(&m)), Some(700.0));
        assert!(rx.recv().unwrap().c.is_ok());
        let h = history.lock().unwrap();
        let stats = h.lookup((33, 44)).expect("completed parent must record");
        assert!(stats.block.warm(), "a ShardedBlock run must tag the block EWMA");
        assert_eq!(stats.block.ewma_ns, 900.0, "engine ns is the shard makespan");
        assert!(!stats.hash.warm());
    }

    #[test]
    fn mixed_measurement_run_is_not_recorded() {
        // one shard reported no measurement (a symbolic-cache replay):
        // recording the other half would hand the planner incomparable
        // numbers, so the whole observation is dropped
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            9,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Ok(shard_output(&m)), None);
        assert!(rx.recv().unwrap().c.is_ok(), "the job itself still succeeds");
        assert!(history.lock().unwrap().is_empty(), "mixed measurements must be dropped");
    }

    #[test]
    fn speculative_first_result_wins_and_late_loser_is_discarded() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        // the backup reports shard 1 first...
        b.complete_from(1, Ok(shard_output(&m)), None, true);
        let r = rx.recv().unwrap();
        assert!(r.c.is_ok());
        // ...and the straggling primary's late report is discarded
        b.complete(1, Ok(shard_output(&m)), None);
        assert!(rx.try_recv().is_err(), "exactly one JobResult");
        let snap = metrics.snapshot();
        assert_eq!(snap.speculative_wins, 1);
        assert_eq!(snap.jobs_completed, 1);
    }

    #[test]
    fn primary_win_does_not_count_as_speculative() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(1, 4, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        assert!(rx.recv().unwrap().c.is_ok());
        assert_eq!(metrics.snapshot().speculative_wins, 0);
    }

    #[test]
    fn abandoning_the_last_chain_fails_the_shard_cleanly() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        b.abandon(1, anyhow!("retry budget exhausted"));
        let r = rx.recv().unwrap();
        let err = format!("{:#}", r.c.unwrap_err());
        assert!(err.contains("retry budget exhausted"), "typed error surfaces: {err}");
        assert_eq!(metrics.snapshot().jobs_failed, 1);
    }

    fn speculating_barrier(
        lag_factor: f64,
        age_ms: u64,
    ) -> (Arc<ShardBarrier>, mpsc::Receiver<JobResult>, Arc<Metrics>) {
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let t0 = Instant::now()
            .checked_sub(std::time::Duration::from_millis(age_ms))
            .expect("backdated t0");
        let mut b = ShardBarrier::new(
            7,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            t0,
            None,
        );
        let a = Arc::new(Csr::identity(8));
        let bb = Arc::new(Csr::identity(4));
        b.set_speculation(SpeculationState {
            cfg: SpeculateConfig { enabled: true, lag_factor, min_lag_ns: 0 },
            a,
            b: bb,
            b_fp: 99,
            measure: false,
            ranges: vec![(0, 4), (4, 8)],
            engine: Engine::Hash,
            block_t: 16,
        });
        (Arc::new(b), rx, metrics)
    }

    #[test]
    fn stragglers_fire_after_quorum_and_lag_threshold_at_most_once() {
        let m = Csr::identity(4);
        let (b, _rx, _metrics) = speculating_barrier(0.5, 20);
        assert!(b.stragglers().is_empty(), "no quorum yet: nothing completed");
        b.complete(0, Ok(shard_output(&m)), None);
        let plans = b.stragglers();
        assert_eq!(plans.len(), 1, "the lagging shard gets one backup");
        assert_eq!(plans[0].shard, 1);
        assert_eq!((plans[0].lo, plans[0].hi), (4, 8));
        assert!(b.stragglers().is_empty(), "each shard speculates at most once");
    }

    #[test]
    fn stragglers_hold_before_the_lag_threshold() {
        let m = Csr::identity(4);
        // lag_factor 1000 × a ~20ms median is far beyond the parent's age
        let (b, _rx, _metrics) = speculating_barrier(1000.0, 20);
        b.complete(0, Ok(shard_output(&m)), None);
        assert!(b.stragglers().is_empty(), "threshold not reached");
    }

    #[test]
    fn abandoned_primary_defers_to_the_in_flight_backup() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = speculating_barrier(0.5, 20);
        b.complete(0, Ok(shard_output(&m)), None);
        assert_eq!(b.stragglers().len(), 1, "backup launched for shard 1");
        // the primary's chain dies — but the backup is still running, so
        // the shard must NOT resolve to an error yet
        b.abandon(1, anyhow!("primary chain died"));
        assert!(rx.try_recv().is_err(), "backup still in flight");
        b.complete_from(1, Ok(shard_output(&m)), None, true);
        let r = rx.recv().unwrap();
        assert!(r.c.is_ok(), "the backup rescued the abandoned shard");
        let snap = metrics.snapshot();
        assert_eq!(snap.speculative_wins, 1);
        assert_eq!(snap.jobs_completed, 1);
        assert_eq!(snap.jobs_failed, 0);
    }

    #[test]
    fn both_chains_dying_surfaces_the_first_error() {
        let m = Csr::identity(4);
        let (b, rx, _metrics) = speculating_barrier(0.5, 20);
        b.complete(0, Ok(shard_output(&m)), None);
        assert_eq!(b.stragglers().len(), 1);
        b.abandon(1, anyhow!("first death"));
        b.abandon(1, anyhow!("second death"));
        let r = rx.recv().unwrap();
        let err = format!("{:#}", r.c.unwrap_err());
        assert!(err.contains("first death"), "the first chain's error wins: {err}");
    }

    #[test]
    fn failed_parent_records_nothing() {
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            8,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Err(anyhow!("injected")), None);
        assert!(rx.recv().unwrap().c.is_err());
        assert!(history.lock().unwrap().is_empty(), "failed runs must not pollute history");
    }
}
