//! Coordinate (triplet) format — the natural target of MatrixMarket parsing
//! and of the synthetic generators; converted to CSR for everything else.

use super::csr::Csr;
use anyhow::{ensure, Result};

/// COO sparse matrix. Entries may be unsorted and contain duplicates;
/// duplicates are summed during CSR conversion (MatrixMarket semantics).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    pub val: Vec<f64>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, row: Vec::new(), col: Vec::new(), val: Vec::new() }
    }

    pub fn with_capacity(rows: usize, cols: usize, cap: usize) -> Self {
        Coo {
            rows,
            cols,
            row: Vec::with_capacity(cap),
            col: Vec::with_capacity(cap),
            val: Vec::with_capacity(cap),
        }
    }

    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.row.push(r as u32);
        self.col.push(c as u32);
        self.val.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.row.len()
    }

    /// Convert to CSR: counting sort by row, in-row sort by column,
    /// duplicate coordinates summed.
    pub fn to_csr(&self) -> Result<Csr> {
        ensure!(
            self.row.len() == self.col.len() && self.col.len() == self.val.len(),
            "COO arrays length mismatch"
        );
        let mut counts = vec![0usize; self.rows + 1];
        for &r in &self.row {
            ensure!((r as usize) < self.rows, "row index {r} out of bounds");
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            counts[i + 1] += counts[i];
        }
        let rpt_raw = counts.clone();
        let mut col = vec![0u32; self.nnz()];
        let mut val = vec![0f64; self.nnz()];
        let mut cursor = rpt_raw.clone();
        for k in 0..self.nnz() {
            let r = self.row[k] as usize;
            let p = cursor[r];
            col[p] = self.col[k];
            val[p] = self.val[k];
            cursor[r] += 1;
        }
        // sort within each row and merge duplicates
        let mut out_rpt = vec![0usize; self.rows + 1];
        let mut out_col = Vec::with_capacity(self.nnz());
        let mut out_val = Vec::with_capacity(self.nnz());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for i in 0..self.rows {
            let (s, e) = (rpt_raw[i], rpt_raw[i + 1]);
            scratch.clear();
            scratch.extend(col[s..e].iter().copied().zip(val[s..e].iter().copied()));
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut last: Option<u32> = None;
            for &(c, v) in scratch.iter() {
                ensure!((c as usize) < self.cols, "col index {c} out of bounds");
                if last == Some(c) {
                    *out_val.last_mut().unwrap() += v;
                } else {
                    out_col.push(c);
                    out_val.push(v);
                    last = Some(c);
                }
            }
            out_rpt[i + 1] = out_col.len();
        }
        Csr::from_parts(self.rows, self.cols, out_rpt, out_col, out_val)
    }
}

impl From<&Csr> for Coo {
    fn from(m: &Csr) -> Self {
        let mut out = Coo::with_capacity(m.rows, m.cols, m.nnz());
        for i in 0..m.rows {
            let (cols, vals) = m.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out.push(i, c as usize, v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coo_to_csr_sorts_rows_and_cols() {
        let mut c = Coo::new(3, 3);
        c.push(2, 1, 4.0);
        c.push(0, 2, 2.0);
        c.push(2, 0, 3.0);
        c.push(0, 0, 1.0);
        let m = c.to_csr().unwrap();
        assert_eq!(m.rpt, vec![0, 2, 2, 4]);
        assert_eq!(m.col, vec![0, 2, 0, 1]);
        assert_eq!(m.val, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut c = Coo::new(1, 2);
        c.push(0, 1, 1.5);
        c.push(0, 1, 2.5);
        c.push(0, 0, 1.0);
        let m = c.to_csr().unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.val, vec![1.0, 4.0]);
    }

    #[test]
    fn roundtrip_csr_coo_csr() {
        let m = Csr::from_parts(2, 4, vec![0, 3, 4], vec![0, 1, 3, 2], vec![1.0, 2.0, 3.0, 4.0])
            .unwrap();
        let back = Coo::from(&m).to_csr().unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let c = Coo { rows: 1, cols: 1, row: vec![0], col: vec![3], val: vec![1.0] };
        assert!(c.to_csr().is_err());
    }
}
