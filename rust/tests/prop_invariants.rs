//! Cross-module property tests (the in-house `util::prop` harness):
//! SpGEMM algebraic identities, CSR invariants through every pipeline,
//! binning partitions, and simulator sanity over random traces.

use opsparse::baselines::Library;
use opsparse::gpusim::{simulate, BlockWork, Kernel, Trace, V100};
use opsparse::sparse::ops::{add, scale, transpose};
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;

fn random_csr(rng: &mut Rng, n: usize, per_row: usize) -> Csr {
    let mut rpt = vec![0usize];
    let mut col = Vec::new();
    let mut val = Vec::new();
    let mut scratch = Vec::new();
    for _ in 0..n {
        let k = rng.range(0, per_row + 1);
        rng.sample_distinct(n, k, &mut scratch);
        for &c in &scratch {
            col.push(c);
            val.push(rng.value());
        }
        rpt.push(col.len());
    }
    Csr::from_parts(n, n, rpt, col, val).unwrap()
}

#[test]
fn prop_every_library_output_is_valid_csr() {
    check(
        "library-valid-csr",
        12,
        40,
        |rng, size| random_csr(rng, size.max(4), 6),
        |a| {
            for lib in Library::all() {
                let out = lib.run(a, a).map_err(|e| format!("{}: {e}", lib.name()))?;
                out.c.validate().map_err(|e| format!("{}: {e}", lib.name()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spgemm_transpose_identity() {
    // (A·B)^T == B^T · A^T
    check(
        "transpose-identity",
        10,
        30,
        |rng, size| {
            let a = random_csr(rng, size.max(4), 5);
            let b = random_csr(rng, size.max(4), 5);
            (a, b)
        },
        |(a, b)| {
            let ab_t = transpose(&spgemm_reference(a, b));
            let bt_at = spgemm_reference(&transpose(b), &transpose(a));
            if ab_t.approx_eq(&bt_at, 1e-9) {
                Ok(())
            } else {
                Err("(AB)^T != B^T A^T".into())
            }
        },
    );
}

#[test]
fn prop_spgemm_distributes_over_addition() {
    // A(B + C) == AB + AC
    check(
        "distributivity",
        10,
        24,
        |rng, size| {
            let n = size.max(4);
            (random_csr(rng, n, 4), random_csr(rng, n, 4), random_csr(rng, n, 4))
        },
        |(a, b, c)| {
            let lhs = spgemm_reference(a, &add(b, c).unwrap());
            let rhs = add(&spgemm_reference(a, b), &spgemm_reference(a, c)).unwrap();
            if lhs.approx_eq(&rhs, 1e-9) {
                Ok(())
            } else {
                Err("A(B+C) != AB + AC".into())
            }
        },
    );
}

#[test]
fn prop_scaling_commutes() {
    // (sA)·B == s(A·B)
    check(
        "scaling",
        10,
        24,
        |rng, size| {
            let n = size.max(4);
            (random_csr(rng, n, 5), random_csr(rng, n, 5), rng.value() * 3.0)
        },
        |(a, b, s)| {
            let lhs = spgemm_reference(&scale(a, *s), b);
            let rhs = scale(&spgemm_reference(a, b), *s);
            if lhs.approx_eq(&rhs, 1e-9) {
                Ok(())
            } else {
                Err("(sA)B != s(AB)".into())
            }
        },
    );
}

#[test]
fn prop_pipeline_equals_reference_on_random_matrices() {
    check(
        "pipeline-vs-reference",
        16,
        60,
        |rng, size| random_csr(rng, size.max(4), 8),
        |a| {
            let out = multiply(a, a, &OpSparseConfig::default()).map_err(|e| e.to_string())?;
            let gold = spgemm_reference(a, a);
            out.c
                .diff(&gold, 1e-9)
                .map_or(Ok(()), |d| Err(d))
        },
    );
}

#[test]
fn prop_simulator_time_monotone_in_work() {
    // doubling every block's bytes must not decrease simulated time
    check(
        "sim-monotone",
        12,
        64,
        |rng, size| {
            let blocks: Vec<BlockWork> = (0..size.max(1))
                .map(|_| BlockWork {
                    global_bytes: rng.below(1_000_000),
                    shared_accesses: rng.below(100_000),
                    ..Default::default()
                })
                .collect();
            blocks
        },
        |blocks| {
            let mk = |mult: u64| {
                let mut t = Trace::new();
                t.launch(Kernel {
                    name: "k".into(),
                    step: "numeric",
                    stream: 0,
                    tb_size: 256,
                    shared_bytes: 8192,
                    blocks: blocks
                        .iter()
                        .map(|b| BlockWork {
                            global_bytes: b.global_bytes * mult,
                            shared_accesses: b.shared_accesses * mult,
                            ..Default::default()
                        })
                        .collect(),
                });
                simulate(&t, &V100).total_ns
            };
            let t1 = mk(1);
            let t2 = mk(2);
            if t2 + 1e-6 >= t1 {
                Ok(())
            } else {
                Err(format!("time decreased: {t1} -> {t2}"))
            }
        },
    );
}

#[test]
fn prop_simulated_kernels_all_complete() {
    check(
        "sim-completion",
        12,
        32,
        |rng, size| {
            let mut t = Trace::new();
            let nk = rng.range(1, 5);
            for i in 0..nk {
                let nblocks = rng.range(1, size.max(2));
                t.launch(Kernel {
                    name: format!("k{i}"),
                    step: "symbolic",
                    stream: rng.range(0, 3),
                    tb_size: [64, 128, 256, 1024][rng.range(0, 4)],
                    shared_bytes: [0usize, 2048, 48 * 1024][rng.range(0, 3)],
                    blocks: vec![
                        BlockWork { global_bytes: rng.below(100_000), ..Default::default() };
                        nblocks
                    ],
                });
                if rng.f64() < 0.3 {
                    t.malloc(rng.below(1 << 20) as usize, "x", "setup");
                }
                if rng.f64() < 0.2 {
                    t.free("x", "cleanup");
                }
            }
            t
        },
        |t| {
            let tl = simulate(t, &V100);
            for k in &tl.kernels {
                if !k.start.is_finite() || !k.end.is_finite() || k.end < k.start {
                    return Err(format!("kernel {} has bad span {}..{}", k.name, k.start, k.end));
                }
            }
            if tl.total_ns <= 0.0 {
                return Err("zero total".into());
            }
            Ok(())
        },
    );
}
