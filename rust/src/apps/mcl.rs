//! Markov clustering (MCL) — the paper's second motivating application
//! [3]: iterate **expansion** (`M ← M²`, a SpGEMM through the OpSparse
//! pipeline), **inflation** (Hadamard power + column re-normalization),
//! and pruning, until the matrix reaches a (near-)idempotent state whose
//! attractor structure defines the clusters.

use super::SpgemmContext;
use crate::sparse::ops::transpose;
use crate::sparse::Csr;
use anyhow::Result;

/// MCL parameters.
#[derive(Clone, Debug)]
pub struct MclParams {
    /// Inflation exponent (classic r = 2).
    pub inflation: f64,
    /// Prune threshold after inflation.
    pub prune: f64,
    /// Convergence threshold on the max column change.
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for MclParams {
    fn default() -> Self {
        MclParams { inflation: 2.0, prune: 1e-4, tol: 1e-6, max_iters: 64 }
    }
}

/// MCL result.
pub struct MclResult {
    /// Cluster id per node.
    pub clusters: Vec<u32>,
    pub iterations: usize,
    /// Total SpGEMM intermediate products across all expansions.
    pub spgemm_products: usize,
}

/// Column-normalize in place (columns sum to 1). Works on the transpose
/// for row access, so takes and returns by value.
fn column_normalize(m: &Csr) -> Csr {
    let mut t = transpose(m);
    for i in 0..t.rows {
        let (s, e) = (t.rpt[i], t.rpt[i + 1]);
        let sum: f64 = t.val[s..e].iter().sum();
        if sum > 0.0 {
            for v in &mut t.val[s..e] {
                *v /= sum;
            }
        }
    }
    transpose(&t)
}

/// Inflation: Hadamard power `r` + prune + column re-normalize.
fn inflate(m: &Csr, r: f64, prune: f64) -> Csr {
    let mut out = m.clone();
    for v in &mut out.val {
        *v = v.powf(r);
    }
    let out = crate::sparse::ops::drop_small(&out, prune);
    column_normalize(&out)
}

/// Max absolute difference between two matrices' common support (and the
/// dropped/added mass), as a cheap convergence measure.
fn max_change(a: &Csr, b: &Csr) -> f64 {
    let mut max = 0.0f64;
    for i in 0..a.rows {
        let (ac, av) = a.row(i);
        let (bc, bv) = b.row(i);
        let (mut p, mut q) = (0usize, 0usize);
        while p < ac.len() || q < bc.len() {
            if p < ac.len() && (q >= bc.len() || ac[p] < bc[q]) {
                max = max.max(av[p].abs());
                p += 1;
            } else if q < bc.len() && (p >= ac.len() || bc[q] < ac[p]) {
                max = max.max(bv[q].abs());
                q += 1;
            } else {
                max = max.max((av[p] - bv[q]).abs());
                p += 1;
                q += 1;
            }
        }
    }
    max
}

/// Extract clusters from a converged MCL matrix: attractors are rows with
/// (near-)nonzero diagonal; every column clusters with the attractors
/// that serve it. We approximate by connected components over the
/// support of `M + Mᵀ` — robust for converged doubly-idempotent states.
fn extract_clusters(m: &Csr) -> Vec<u32> {
    let n = m.rows;
    let t = transpose(m);
    let mut id: Vec<i64> = vec![-1; n];
    let mut next = 0u32;
    let mut stack: Vec<usize> = Vec::new();
    for s in 0..n {
        if id[s] >= 0 {
            continue;
        }
        id[s] = next as i64;
        stack.push(s);
        while let Some(u) = stack.pop() {
            for &c in m.row_cols(u).iter().chain(t.row_cols(u)) {
                let v = c as usize;
                if id[v] < 0 {
                    id[v] = next as i64;
                    stack.push(v);
                }
            }
        }
        next += 1;
    }
    id.into_iter().map(|x| x as u32).collect()
}

/// Run MCL on an (undirected) adjacency matrix with a fresh context.
pub fn mcl(adjacency: &Csr, params: &MclParams) -> Result<MclResult> {
    mcl_with(&mut SpgemmContext::new(), adjacency, params)
}

/// MCL through a caller-owned [`SpgemmContext`]: as the clustering
/// converges the expansion pattern stabilizes, so late iterations (and
/// any outer loop re-running MCL on the same graph) replay the cached
/// symbolic phase and recycle the pool's allocations.
pub fn mcl_with(
    ctx: &mut SpgemmContext,
    adjacency: &Csr,
    params: &MclParams,
) -> Result<MclResult> {
    // add self loops (standard MCL practice) and normalize
    let with_loops = crate::sparse::ops::add(adjacency, &Csr::identity(adjacency.rows))?;
    let mut m = column_normalize(&with_loops);
    let mut products = 0usize;
    let mut iters = 0usize;
    for _ in 0..params.max_iters {
        iters += 1;
        let expanded = ctx.multiply(&m, &m)?; // expansion via OpSparse
        products += expanded.nprod;
        let next = inflate(&expanded.c, params.inflation, params.prune);
        let delta = max_change(&next, &m);
        m = next;
        if delta < params.tol {
            break;
        }
    }
    Ok(MclResult { clusters: extract_clusters(&m), iterations: iters, spgemm_products: products })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    /// Two dense cliques joined by a single weak edge.
    fn two_cliques(k: usize) -> Csr {
        let n = 2 * k;
        let mut coo = Coo::new(n, n);
        for a in 0..k {
            for b in 0..k {
                if a != b {
                    coo.push(a, b, 1.0);
                    coo.push(k + a, k + b, 1.0);
                }
            }
        }
        coo.push(0, k, 0.1);
        coo.push(k, 0, 0.1);
        coo.to_csr().unwrap()
    }

    #[test]
    fn separates_two_cliques() {
        let g = two_cliques(6);
        let r = mcl(&g, &MclParams::default()).unwrap();
        assert!(r.iterations >= 2);
        assert!(r.spgemm_products > 0);
        // all of clique 1 in one cluster, clique 2 in another
        let c0 = r.clusters[0];
        let c1 = r.clusters[6];
        assert_ne!(c0, c1, "cliques must split");
        for i in 0..6 {
            assert_eq!(r.clusters[i], c0, "node {i}");
            assert_eq!(r.clusters[6 + i], c1, "node {}", 6 + i);
        }
    }

    #[test]
    fn context_run_matches_fresh_run_and_pools() {
        let g = two_cliques(6);
        let fresh = mcl(&g, &MclParams::default()).unwrap();
        let mut ctx = SpgemmContext::new();
        let ctxed = mcl_with(&mut ctx, &g, &MclParams::default()).unwrap();
        assert_eq!(fresh.clusters, ctxed.clusters);
        assert_eq!(fresh.iterations, ctxed.iterations);
        // every expansion went through the pool; re-running the converged
        // workload replays its symbolic phases from the cache
        assert!(ctx.pool_stats().requests > 0);
        let hits_before = ctx.sym_cache_hits();
        let _ = mcl_with(&mut ctx, &g, &MclParams::default()).unwrap();
        assert!(
            ctx.sym_cache_hits() > hits_before,
            "second MCL run over the same graph must hit the symbolic cache"
        );
    }

    #[test]
    fn column_normalize_columns_sum_to_one() {
        let g = two_cliques(4);
        let m = column_normalize(&g);
        let t = transpose(&m);
        for j in 0..t.rows {
            let s: f64 = t.row_vals(j).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "column {j} sums to {s}");
        }
    }

    #[test]
    fn single_component_is_one_cluster() {
        let g = two_cliques(4);
        // strengthen the bridge so everything merges
        let mut g = g;
        for (i, &c) in g.col.clone().iter().enumerate() {
            let _ = c;
            g.val[i] = 1.0;
        }
        let r = mcl(&Csr::identity(5), &MclParams::default()).unwrap();
        // identity graph: every node is its own cluster
        assert_eq!(r.clusters, vec![0, 1, 2, 3, 4]);
    }
}
