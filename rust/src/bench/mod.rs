//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§6) on the synthetic suite. Shared by the CLI
//! (`opsparse bench <target>`) and the `cargo bench` targets.

pub mod chaos_bench;
pub mod corpus;
pub mod engines;
pub mod figures;
pub mod serve_bench;
pub mod tables;
pub mod trace_bench;

use crate::gpusim::{simulate, Timeline, V100};
use crate::sparse::Csr;
use crate::spgemm::pipeline::SpgemmOutput;
use anyhow::Result;

/// Run one library on `A*A`, validate against the reference, and simulate
/// the device trace. Returns (output, timeline).
pub fn run_and_simulate(
    lib: crate::baselines::Library,
    a: &Csr,
    verify: bool,
) -> Result<(SpgemmOutput, Timeline)> {
    let out = lib.run(a, a)?;
    if verify {
        let gold = crate::spgemm::reference::spgemm_reference(a, a);
        if let Some(d) = out.c.diff(&gold, 1e-9) {
            anyhow::bail!("{} result mismatch: {d}", lib.name());
        }
    }
    let tl = simulate(&out.trace, &V100);
    Ok((out, tl))
}

/// GFLOPS under the simulated timeline (the paper's metric: 2·n_prod/t).
pub fn gflops(out: &SpgemmOutput, tl: &Timeline) -> f64 {
    tl.gflops(out.flops())
}

/// Serialize figure rows as a small JSON document (no serde in the
/// dependency set). Used by CI to record `BENCH_seed.json` baselines:
/// `{"bench": ..., "scale": ..., "libs": [...], "rows": [{"matrix": ...,
/// "gflops": [...]}]}`.
pub fn write_rows_json(
    path: &str,
    bench: &str,
    scale: crate::gen::suite::SuiteScale,
    libs: &[&str],
    rows: &[(String, Vec<f64>)],
) -> Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"{}\",\n  \"scale\": \"{:?}\",\n  \"libs\": [{}],\n  \"rows\": [\n",
        esc(bench),
        scale,
        libs.iter().map(|l| format!("\"{}\"", esc(l))).collect::<Vec<_>>().join(", ")
    ));
    for (i, (name, vals)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"matrix\": \"{}\", \"gflops\": [{}]}}{}\n",
            esc(name),
            vals.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(", "),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize shard-scaling rows as JSON (no serde in the dependency
/// set). CI records `BENCH_shards.json` this way, next to
/// `BENCH_seed.json`, so later PRs can compare the multi-device scaling
/// trajectory — makespan split into compute vs broadcast vs gather, plus
/// the honest efficiency figure.
pub fn write_shard_scaling_json(
    path: &str,
    scale: crate::gen::suite::SuiteScale,
    rows: &[figures::ShardScalingRow],
) -> Result<()> {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"shard_scaling\",\n  \"scale\": \"{scale:?}\",\n  \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"shards\": {}, \"makespan_ns\": {:.1}, \"overlapped_makespan_ns\": {:.1}, \
             \"overlap_saved_ns\": {:.1}, \"compute_ns\": {:.1}, \
             \"broadcast_ns\": {:.1}, \"gather_ns\": {:.1}, \"plan_imbalance\": {:.4}, \
             \"time_imbalance\": {:.4}, \"speedup\": {:.4}, \"efficiency\": {:.4}, \
             \"efficiency_overlapped\": {:.4}}}{}\n",
            r.shards,
            r.makespan_ns,
            r.overlapped_makespan_ns,
            r.overlap_saved_ns,
            r.compute_ns,
            r.broadcast_ns,
            r.gather_ns,
            r.plan_imbalance,
            r.time_imbalance,
            r.speedup,
            r.efficiency,
            r.efficiency_overlapped,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Render the shared `"gates"` JSON fragment: one [`stats::GateResult`]
/// verdict per blocking check, so the python CI gates read a hypothesis
/// test's conclusion instead of re-deriving a point comparison.
pub fn gates_json_fragment(gates: &[crate::util::stats::GateResult]) -> String {
    let body =
        gates.iter().map(|g| format!("    {}", g.to_json())).collect::<Vec<_>>().join(",\n");
    if body.is_empty() {
        "  \"gates\": []".to_string()
    } else {
        format!("  \"gates\": [\n{body}\n  ]")
    }
}

/// Serialize the serial-vs-overlapped makespan ablation as JSON:
/// `BENCH_overlap.json`, uploaded by CI next to `BENCH_shards.json` and
/// consumed by the blocking overlap-dominance check there. The rows are
/// the seed-2026 repetition (display continuity); the verdict CI blocks
/// on is the embedded Welch-gate object from the adaptive repetition
/// loop. The file is a contract, keep it small.
pub fn write_overlap_json(
    path: &str,
    scale: crate::gen::suite::SuiteScale,
    rows: &[figures::ShardScalingRow],
    gates: &[crate::util::stats::GateResult],
) -> Result<()> {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"overlap_ablation\",\n  \"scale\": \"{scale:?}\",\n  \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"shards\": {}, \"serial_makespan_ns\": {:.1}, \
             \"overlapped_makespan_ns\": {:.1}, \"overlap_saved_ns\": {:.1}}}{}\n",
            r.shards,
            r.makespan_ns,
            r.overlapped_makespan_ns,
            r.overlap_saved_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!("  ],\n{}\n}}\n", gates_json_fragment(gates)));
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the adaptive re-planning ablation as JSON:
/// `BENCH_adaptive.json`, uploaded by CI next to `BENCH_shards.json` /
/// `BENCH_overlap.json` and consumed by the blocking warm-≤-cold check
/// there. One row per (family, shard count): the cold proxy-planned
/// makespan, the warm (kept-plan) makespan, and the raw re-cut figure
/// before rollback. The blocking verdict is the embedded Welch-gate
/// object from the adaptive repetition loop, not the single-seed rows.
pub fn write_adaptive_json(
    path: &str,
    scale: crate::gen::suite::SuiteScale,
    rows: &[figures::AdaptiveRow],
    gates: &[crate::util::stats::GateResult],
) -> Result<()> {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"adaptive_replan\",\n  \"scale\": \"{scale:?}\",\n  \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"family\": \"{}\", \"shards\": {}, \"cold_makespan_ns\": {:.1}, \
             \"warm_makespan_ns\": {:.1}, \"replanned_makespan_ns\": {:.1}, \
             \"cold_imbalance\": {:.4}, \"warm_imbalance\": {:.4}, \"kept_replan\": {}}}{}\n",
            r.family,
            r.shards,
            r.cold_makespan_ns,
            r.warm_makespan_ns,
            r.replanned_makespan_ns,
            r.cold_imbalance,
            r.warm_imbalance,
            r.kept_replan,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!("  ],\n{}\n}}\n", gates_json_fragment(gates)));
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the serving-front-door bench as JSON: `BENCH_serve.json`,
/// uploaded by CI next to the other `BENCH_*.json` baselines and
/// consumed by the blocking checks there (coalesced throughput ≥
/// uncoalesced, `sym_executions == 1` with `coalesce_hits == jobs − 1`
/// on the coalesced row, bit-identical fan-out, persistence route
/// stability, and all-knobs-off baseline parity). One row per mode plus
/// the two verdict booleans — the file is a contract, keep it small.
pub fn write_serve_json(path: &str, report: &serve_bench::ServeBenchReport) -> Result<()> {
    fn opt(v: Option<u64>) -> String {
        v.map(|x| x.to_string()).unwrap_or_else(|| "null".to_string())
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"serve\",\n  \"scale\": \"{:?}\",\n  \"jobs\": {},\n  \"rows\": [\n",
        report.scale, report.jobs
    ));
    for (i, r) in report.rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"jobs\": {}, \"wall_ns\": {}, \
             \"throughput_jobs_per_s\": {:.4}, \"executed_jobs\": {}, \"sym_executions\": {}, \
             \"coalesce_hits\": {}, \"rejected_jobs\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \
             \"queue_depth_max\": {}, \"bit_identical\": {}}}{}\n",
            r.mode,
            r.jobs,
            r.wall_ns,
            r.throughput_jobs_per_s,
            r.executed_jobs,
            r.sym_executions,
            r.coalesce_hits,
            r.rejected_jobs,
            opt(r.p50_ns),
            opt(r.p99_ns),
            r.queue_depth_max,
            r.bit_identical,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!(
        "  ],\n  \"persist_route_stable\": {},\n  \"baseline_match\": {},\n{}\n}}\n",
        report.persist_route_stable,
        report.baseline_match,
        gates_json_fragment(&report.gates)
    ));
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the chaos bench as JSON: `BENCH_chaos.json`, uploaded by
/// the CI chaos job and consumed by the blocking checks there (gentle
/// rows complete 100%, every row bit-identical, no hangs). One row per
/// (preset × speculation) — the file is a contract, keep it small.
pub fn write_chaos_json(path: &str, report: &chaos_bench::ChaosReport) -> Result<()> {
    fn opt(v: Option<u64>) -> String {
        v.map(|x| x.to_string()).unwrap_or_else(|| "null".to_string())
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"chaos\",\n  \"jobs\": {},\n  \"seed\": {},\n  \
         \"gentle_completed\": {},\n  \"gentle_total\": {},\n  \"rows\": [\n",
        report.jobs, report.seed, report.gentle_completed, report.gentle_total
    ));
    for (i, r) in report.rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"preset\": \"{}\", \"speculate\": {}, \"jobs\": {}, \"completed\": {}, \
             \"failed\": {}, \"completion_rate\": {:.4}, \"bit_identical\": {}, \"hung\": {}, \
             \"p50_makespan_ns\": {}, \"p99_makespan_ns\": {}, \"worker_deaths\": {}, \
             \"requeued_shards\": {}, \"speculative_launches\": {}, \"speculative_wins\": {}}}{}\n",
            r.preset,
            r.speculate,
            r.jobs,
            r.completed,
            r.failed,
            r.completion_rate,
            r.bit_identical,
            r.hung,
            opt(r.p50_makespan_ns),
            opt(r.p99_makespan_ns),
            r.worker_deaths,
            r.requeued_shards,
            r.speculative_launches,
            r.speculative_wins,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!("  ],\n{}\n}}\n", gates_json_fragment(&report.gates)));
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the real-matrix corpus harness as JSON: `BENCH_corpus.json`,
/// uploaded by CI and consumed by the blocking corpus check there
/// (≥ [`corpus::MIN_REAL_FIXTURES`] checked-in fixtures, every matrix
/// bit-identical across the unsharded/sharded/serve paths, a positive
/// speedup figure per matrix).
pub fn write_corpus_json(path: &str, report: &corpus::CorpusReport) -> Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"corpus\",\n  \"dir\": \"{}\",\n  \"fixtures\": {},\n  \
         \"synthesized\": {},\n  \"min_real_fixtures\": {},\n  \"all_bit_identical\": {},\n  \
         \"rows\": [\n",
        esc(&report.dir),
        report.fixtures,
        report.synthesized,
        corpus::MIN_REAL_FIXTURES,
        report.all_bit_identical
    ));
    for (i, r) in report.rows.iter().enumerate() {
        let occ =
            r.bin_occupancy.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ");
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"source\": \"{}\", \"rows\": {}, \"nnz\": {}, \
             \"route\": \"{}\", \"opsparse_ns\": {:.1}, \"cusparse_ns\": {:.1}, \
             \"speedup_vs_cusparse\": {:.4}, \"gflops\": {:.4}, \"makespan_ns\": {:.1}, \
             \"bin_occupancy\": [{}], \"fast_path\": {}, \"bit_identical_sharded\": {}, \
             \"bit_identical_serve\": {}, \"mmio_roundtrip\": {}}}{}\n",
            esc(&r.name),
            r.source,
            r.rows,
            r.nnz,
            esc(&r.route),
            r.opsparse_ns,
            r.cusparse_ns,
            r.speedup_vs_cusparse,
            r.gflops,
            r.makespan_ns,
            occ,
            r.fast_path,
            r.bit_identical_sharded,
            r.bit_identical_serve,
            r.mmio_roundtrip,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the engine-dispatch ablation as JSON: `BENCH_engines.json`,
/// uploaded by CI next to the other `BENCH_*.json` baselines and consumed
/// by the blocking engine gates there (per class: dispatched statistically
/// no worse than the better fixed engine; on the blocky/FEM classes,
/// dispatched strictly faster than fixed hash; the native block engine
/// bitwise identical to the hash pipeline on every seed). One row per
/// class plus the embedded Welch-gate verdicts — the file is a contract,
/// keep it small.
pub fn write_engines_json(path: &str, report: &engines::EnginesReport) -> Result<()> {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"bench\": \"engines\",\n  \"reps\": {},\n  \"all_bit_identical\": {},\n  \
         \"rows\": [\n",
        report.reps, report.all_bit_identical
    ));
    for (i, r) in report.rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"class\": \"{}\", \"blocky\": {}, \"reps\": {}, \
             \"hash_ns_mean\": {:.1}, \"block_ns_mean\": {:.1}, \
             \"dispatched_ns_mean\": {:.1}, \"dispatched_block_picks\": {}, \
             \"cold_agreed\": {}, \"bit_identical\": {}}}{}\n",
            r.class,
            r.blocky,
            r.reps,
            r.hash_ns_mean,
            r.block_ns_mean,
            r.dispatched_ns_mean,
            r.dispatched_block_picks,
            r.cold_agreed,
            r.bit_identical,
            if i + 1 < report.rows.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!("  ],\n{}\n}}\n", gates_json_fragment(&report.gates)));
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Serialize the tracing bench as JSON: `BENCH_trace.json`, uploaded by
/// CI next to the other `BENCH_*.json` baselines and consumed by the
/// blocking trace checks there (the embedded Welch overhead gate, the
/// well-formedness verdict, every contract request resolved). The
/// contract run's Chrome trace itself is written separately (see
/// `write_trace_events`) for the python schema validator — this report
/// only carries the figures.
pub fn write_trace_json(path: &str, report: &trace_bench::TraceBenchReport) -> Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let err = match &report.well_formed_err {
        Some(e) => format!("\"{}\"", esc(e)),
        None => "null".to_string(),
    };
    let out = format!(
        "{{\n  \"bench\": \"trace\",\n  \"jobs\": {},\n  \
         \"off_throughput_jobs_per_s\": {:.4},\n  \
         \"on_throughput_jobs_per_s\": {:.4},\n  \"spans\": {},\n  \"instants\": {},\n  \
         \"chaos_instants\": {},\n  \"shard_spans\": {},\n  \"slow_exemplars\": {},\n  \
         \"dropped_spans\": {},\n  \"well_formed\": {},\n  \"well_formed_err\": {},\n  \
         \"completed\": {},\n{}\n}}\n",
        report.jobs,
        report.off_throughput_jobs_per_s,
        report.on_throughput_jobs_per_s,
        report.spans,
        report.instants,
        report.chaos_instants,
        report.shard_spans,
        report.slow_exemplars,
        report.dropped_spans,
        report.well_formed,
        err,
        report.completed,
        gates_json_fragment(&report.gates)
    );
    std::fs::write(path, out)?;
    println!("wrote {path}");
    Ok(())
}

/// Write the trace bench's contract-run Chrome trace-event JSON, the
/// file the CI python validator loads and structurally checks.
pub fn write_trace_events(path: &str, report: &trace_bench::TraceBenchReport) -> Result<()> {
    std::fs::write(path, &report.chrome_json)?;
    println!("wrote {path}");
    Ok(())
}

/// §Perf harness: median wall time of `multiply()` on a named suite
/// matrix (used by `opsparse bench perf` and the EXPERIMENTS.md log).
pub fn perf_l3(matrix: &str, scale: crate::gen::suite::SuiteScale, reps: usize) -> Result<f64> {
    let e = crate::gen::suite::suite_entry(matrix)
        .ok_or_else(|| anyhow::anyhow!("unknown matrix {matrix}"))?;
    let a = e.generate(scale);
    let cfg = crate::spgemm::pipeline::OpSparseConfig::default();
    // warmup
    let out = crate::spgemm::pipeline::multiply(&a, &a, &cfg)?;
    let mut times: Vec<f64> = Vec::new();
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        let o = crate::spgemm::pipeline::multiply(&a, &a, &cfg)?;
        times.push(t0.elapsed().as_secs_f64() * 1e9);
        std::hint::black_box(o.c.nnz());
    }
    times.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let med = times[times.len() / 2];
    println!(
        "perf_l3 {matrix}@{scale:?}: median {} over {reps} reps ({} products, {:.1} Mprod/s)",
        crate::util::fmt::ns(med),
        crate::util::fmt::count(out.nprod),
        out.nprod as f64 * 1e3 / med
    );
    Ok(med)
}
