//! Service metrics: counters plus latency percentiles computed from a
//! bounded reservoir of observed job latencies, extended with the
//! allocation-reuse counters the pool/cache layer reports (device mallocs
//! avoided, symbolic phases skipped), per-phase latency histograms, and
//! Prometheus text-format exposition
//! ([`Metrics::to_prometheus`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Histogram bucket upper bounds in ns (1–2–5 series, 1µs .. 5s). The
/// implicit `+Inf` bucket comes after these.
pub const LATENCY_BUCKETS_NS: [u64; 21] = [
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
    100_000_000,
    200_000_000,
    500_000_000,
    1_000_000_000,
    2_000_000_000,
    5_000_000_000,
];

/// A lock-free fixed-bucket latency histogram (cumulative-on-export,
/// per-bucket atomics internally). Observation is a couple of relaxed
/// atomic adds — cheap enough for every job and serve fan-out.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; LATENCY_BUCKETS_NS.len() + 1],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, ns: u64) {
        let idx = LATENCY_BUCKETS_NS.partition_point(|&b| b < ns);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Append this histogram as one labeled Prometheus series
    /// (`_bucket{phase=..,le=..}` cumulative counts, `_sum`, `_count`).
    fn render_prometheus(&self, out: &mut String, family: &str, phase: &str) {
        let mut cum = 0u64;
        for (i, bound) in LATENCY_BUCKETS_NS.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            out.push_str(&format!(
                "{family}_bucket{{phase=\"{phase}\",le=\"{bound}\"}} {cum}\n"
            ));
        }
        cum += self.buckets[LATENCY_BUCKETS_NS.len()].load(Ordering::Relaxed);
        out.push_str(&format!("{family}_bucket{{phase=\"{phase}\",le=\"+Inf\"}} {cum}\n"));
        out.push_str(&format!("{family}_sum{{phase=\"{phase}\"}} {}\n", self.sum_ns()));
        out.push_str(&format!("{family}_count{{phase=\"{phase}\"}} {}\n", self.count()));
    }
}

/// Per-phase latency histograms, one per span kind of the request
/// lifecycle. The coarse phases (`exec`, `serve_total`) are fed by the
/// existing latency observation points and fill regardless of tracing;
/// the fine phases are fed by the `obs` span hooks and stay at zero
/// with `--trace off` (the hot path then performs no extra clock
/// reads).
#[derive(Debug, Default)]
pub struct PhaseHistograms {
    /// Front-door admission (lock + coalesce/queue bookkeeping).
    pub admit: Histogram,
    /// Pending-queue residency: admission → handed to the coordinator.
    pub queue_wait: Histogram,
    /// Time a hash-routed job sat in an open batch before flushing.
    pub batch_residency: Histogram,
    /// The router's route/engine decision.
    pub route_decision: Histogram,
    /// Whole-job execution on a worker (submit → result, any route).
    pub exec: Histogram,
    /// One shard sub-job attempt on its worker.
    pub shard_exec: Histogram,
    /// Barrier reassembly of a sharded result.
    pub stitch: Histogram,
    /// Admission → fan-out as one waiter saw it.
    pub serve_total: Histogram,
}

impl PhaseHistograms {
    /// Name → histogram, in exposition order.
    pub fn iter(&self) -> [(&'static str, &Histogram); 8] {
        [
            ("admit", &self.admit),
            ("queue_wait", &self.queue_wait),
            ("batch_residency", &self.batch_residency),
            ("route_decision", &self.route_decision),
            ("exec", &self.exec),
            ("shard_exec", &self.shard_exec),
            ("stitch", &self.stitch),
            ("serve_total", &self.serve_total),
        ]
    }
}

/// Thread-safe metrics registry for the coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub hash_routed: AtomicU64,
    pub block_routed: AtomicU64,
    /// Jobs routed to the row-sharded multi-device path (working set over
    /// the single-device budget and worth the replication cost).
    pub sharded_routed: AtomicU64,
    /// Jobs routed to the block-row-sharded multi-device block engine
    /// (`Route::ShardedBlock`): T-aligned cuts, one native BSR engine
    /// per shard sub-job.
    pub sharded_block_routed: AtomicU64,
    /// Auto/fill-routed block jobs that fell back to the hash pipeline
    /// because no block engine was loaded. Previously a silent
    /// downgrade; now counted (and logged once per coordinator).
    pub block_fallbacks: AtomicU64,
    /// Shard sub-jobs executed by hash workers (cross-worker fan-out).
    pub shard_subjobs: AtomicU64,
    /// Ids of the workers that have executed at least one shard sub-job —
    /// the telemetry proving a sharded job's shards actually spread over
    /// the pool instead of serializing on one worker.
    shard_worker_ids: Mutex<std::collections::BTreeSet<usize>>,
    /// Total intermediate products processed (throughput numerator).
    pub nprod_total: AtomicU64,
    /// Jobs whose symbolic phase was replayed from the pattern cache.
    pub sym_cache_hits: AtomicU64,
    /// Jobs that computed (and cached) their symbolic phase.
    pub sym_cache_misses: AtomicU64,
    /// Shard sub-jobs whose symbolic phase was replayed via the
    /// shard-aware cache keys `(fingerprint(A[lo..hi]), fingerprint(B))`.
    pub shard_sym_cache_hits: AtomicU64,
    /// Shard sub-jobs that computed (and cached) their symbolic phase.
    pub shard_sym_cache_misses: AtomicU64,
    /// Sharded jobs planned from the execution history (a warm pattern
    /// with measured per-shard timings was consulted; the measured
    /// re-cut is applied only when it improves the modeled makespan —
    /// `BENCH_adaptive.json`'s `kept_replan` tracks that split).
    pub replans: AtomicU64,
    /// Sharded jobs that fell back to the `nprod` proxy plan because the
    /// pattern had no recorded history (cold).
    pub replan_cold_misses: AtomicU64,
    /// Measured job executions folded into the live `ns_per_prod` fit.
    pub refit_updates: AtomicU64,
    /// Patterns currently held by the execution history (gauge).
    pub history_patterns: AtomicU64,
    /// Patterns evicted from the execution history so far (gauge).
    pub history_evictions: AtomicU64,
    /// Real `cudaMalloc` calls issued through the workers' device pools.
    pub pool_device_mallocs: AtomicU64,
    /// Bytes those mallocs reserved (the fleet's grow-only footprint).
    pub pool_device_bytes: AtomicU64,
    /// Allocation requests served from recycled pool buckets.
    pub pool_hits: AtomicU64,
    /// Bytes served from recycled buckets instead of `cudaMalloc`.
    pub pool_reused_bytes: AtomicU64,
    /// End-to-end (submit → result) latency samples in ns, bounded
    /// reservoir — every route measures from submit, so queue wait is
    /// visible and percentiles compare across routes.
    latencies: Mutex<Vec<u64>>,
    /// Serving front door: requests that attached to an identical
    /// in-flight request instead of executing (N identical concurrent
    /// requests count N−1 hits).
    pub coalesce_hits: AtomicU64,
    /// Requests refused at admission (`Rejected { queue_full }`).
    pub rejected_jobs: AtomicU64,
    /// Batches flushed to workers (each is one `RunBatch` visit).
    pub batches: AtomicU64,
    /// Jobs that rode inside those batches.
    pub batched_jobs: AtomicU64,
    /// Current front-door queue depth: admitted-but-unfinished leader
    /// requests (gauge; waiters coalesced onto a leader don't count).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub queue_depth_max: AtomicU64,
    /// Front-door latency samples in ns (admission → fan-out, per
    /// waiter), bounded like `latencies`. Kept separate because a
    /// coalesced waiter observes a latency no coordinator job ever ran.
    serve_latencies: Mutex<Vec<u64>>,
    /// Speculative backup sub-jobs launched for lagging shards.
    pub speculative_launches: AtomicU64,
    /// Shards whose *backup* reported first (the straggler's result,
    /// when it eventually lands, is discarded — first result wins).
    pub speculative_wins: AtomicU64,
    /// Shard sub-jobs requeued off a dead worker onto the surviving
    /// fleet (each requeue is one death survived by the parent job).
    pub requeued_shards: AtomicU64,
    /// Whole hash jobs / batches requeued off a dead worker.
    pub requeued_jobs: AtomicU64,
    /// Workers that died (chaos kill) — each spawns one replacement.
    pub worker_deaths: AtomicU64,
    /// Chaos-injected straggler delays applied at sub-job boundaries.
    pub chaos_delays: AtomicU64,
    /// Chaos-injected device-pool teardowns (simulated memory pressure).
    pub chaos_pool_shrinks: AtomicU64,
    /// Per-phase latency histograms (Prometheus-exposed; not part of
    /// [`MetricsSnapshot`], so snapshots stay `Copy` and bit-stable).
    pub phases: PhaseHistograms,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_latency(&self, ns: u64) {
        self.phases.exec.observe(ns);
        let mut l = self.latencies.lock().unwrap();
        if l.len() < 65_536 {
            l.push(ns);
        }
    }

    /// Record one front-door (admission → fan-out) latency sample.
    pub fn observe_serve_latency(&self, ns: u64) {
        self.phases.serve_total.observe(ns);
        let mut l = self.serve_latencies.lock().unwrap();
        if l.len() < 65_536 {
            l.push(ns);
        }
    }

    /// Move the queue-depth gauge, tracking its high-water mark.
    pub fn observe_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_max.fetch_max(depth, Ordering::Relaxed);
    }

    /// Front-door latency percentile (0.0..=1.0) over recorded samples.
    pub fn serve_latency_percentile(&self, q: f64) -> Option<u64> {
        let mut l = self.serve_latencies.lock().unwrap().clone();
        if l.is_empty() {
            return None;
        }
        l.sort_unstable();
        let idx = ((l.len() as f64 - 1.0) * q).round() as usize;
        Some(l[idx.min(l.len() - 1)])
    }

    /// Record that `worker_id` picked up one shard sub-job.
    pub fn observe_shard_subjob(&self, worker_id: usize) {
        self.shard_subjobs.fetch_add(1, Ordering::Relaxed);
        self.shard_worker_ids.lock().unwrap().insert(worker_id);
    }

    /// Distinct workers that have executed shard sub-jobs.
    pub fn distinct_shard_workers(&self) -> u64 {
        self.shard_worker_ids.lock().unwrap().len() as u64
    }

    /// Fold one pool-stats delta (one job's worth) into the registry.
    pub fn observe_pool(&self, d: &crate::gpusim::PoolStats) {
        self.pool_device_mallocs.fetch_add(d.device_mallocs, Ordering::Relaxed);
        self.pool_device_bytes.fetch_add(d.device_bytes, Ordering::Relaxed);
        self.pool_hits.fetch_add(d.pool_hits, Ordering::Relaxed);
        self.pool_reused_bytes.fetch_add(d.reused_bytes, Ordering::Relaxed);
    }

    /// Latency percentile (0.0..=1.0) over the recorded samples.
    pub fn latency_percentile(&self, q: f64) -> Option<u64> {
        let mut l = self.latencies.lock().unwrap().clone();
        if l.is_empty() {
            return None;
        }
        l.sort_unstable();
        let idx = ((l.len() as f64 - 1.0) * q).round() as usize;
        Some(l[idx.min(l.len() - 1)])
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            hash_routed: self.hash_routed.load(Ordering::Relaxed),
            block_routed: self.block_routed.load(Ordering::Relaxed),
            sharded_routed: self.sharded_routed.load(Ordering::Relaxed),
            sharded_block_routed: self.sharded_block_routed.load(Ordering::Relaxed),
            block_fallbacks: self.block_fallbacks.load(Ordering::Relaxed),
            shard_subjobs: self.shard_subjobs.load(Ordering::Relaxed),
            shard_workers: self.distinct_shard_workers(),
            nprod_total: self.nprod_total.load(Ordering::Relaxed),
            sym_cache_hits: self.sym_cache_hits.load(Ordering::Relaxed),
            sym_cache_misses: self.sym_cache_misses.load(Ordering::Relaxed),
            shard_sym_cache_hits: self.shard_sym_cache_hits.load(Ordering::Relaxed),
            shard_sym_cache_misses: self.shard_sym_cache_misses.load(Ordering::Relaxed),
            replans: self.replans.load(Ordering::Relaxed),
            replan_cold_misses: self.replan_cold_misses.load(Ordering::Relaxed),
            refit_updates: self.refit_updates.load(Ordering::Relaxed),
            history_patterns: self.history_patterns.load(Ordering::Relaxed),
            history_evictions: self.history_evictions.load(Ordering::Relaxed),
            pool_device_mallocs: self.pool_device_mallocs.load(Ordering::Relaxed),
            pool_device_bytes: self.pool_device_bytes.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_reused_bytes: self.pool_reused_bytes.load(Ordering::Relaxed),
            coalesce_hits: self.coalesce_hits.load(Ordering::Relaxed),
            rejected_jobs: self.rejected_jobs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_jobs: self.batched_jobs.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            queue_depth_max: self.queue_depth_max.load(Ordering::Relaxed),
            speculative_launches: self.speculative_launches.load(Ordering::Relaxed),
            speculative_wins: self.speculative_wins.load(Ordering::Relaxed),
            requeued_shards: self.requeued_shards.load(Ordering::Relaxed),
            requeued_jobs: self.requeued_jobs.load(Ordering::Relaxed),
            worker_deaths: self.worker_deaths.load(Ordering::Relaxed),
            chaos_delays: self.chaos_delays.load(Ordering::Relaxed),
            chaos_pool_shrinks: self.chaos_pool_shrinks.load(Ordering::Relaxed),
            p50_ns: self.latency_percentile(0.50),
            p99_ns: self.latency_percentile(0.99),
            serve_p50_ns: self.serve_latency_percentile(0.50),
            serve_p99_ns: self.serve_latency_percentile(0.99),
        }
    }

    /// The whole registry in Prometheus text exposition format: every
    /// counter and gauge of the snapshot (prefixed `opsparse_`), the
    /// latency percentiles when samples exist, and the per-phase
    /// latency histograms (`opsparse_phase_latency_ns` with a `phase`
    /// label). The metrics/snapshot/Display drift test also pins every
    /// `Metrics` counter into this exposition.
    pub fn to_prometheus(&self) -> String {
        let s = self.snapshot();
        let counters: [(&str, u64); 33] = [
            ("jobs_submitted", s.jobs_submitted),
            ("jobs_completed", s.jobs_completed),
            ("jobs_failed", s.jobs_failed),
            ("hash_routed", s.hash_routed),
            ("block_routed", s.block_routed),
            ("sharded_routed", s.sharded_routed),
            ("sharded_block_routed", s.sharded_block_routed),
            ("block_fallbacks", s.block_fallbacks),
            ("shard_subjobs", s.shard_subjobs),
            ("nprod_total", s.nprod_total),
            ("sym_cache_hits", s.sym_cache_hits),
            ("sym_cache_misses", s.sym_cache_misses),
            ("shard_sym_cache_hits", s.shard_sym_cache_hits),
            ("shard_sym_cache_misses", s.shard_sym_cache_misses),
            ("replans", s.replans),
            ("replan_cold_misses", s.replan_cold_misses),
            ("refit_updates", s.refit_updates),
            ("history_evictions", s.history_evictions),
            ("pool_device_mallocs", s.pool_device_mallocs),
            ("pool_device_bytes", s.pool_device_bytes),
            ("pool_hits", s.pool_hits),
            ("pool_reused_bytes", s.pool_reused_bytes),
            ("coalesce_hits", s.coalesce_hits),
            ("rejected_jobs", s.rejected_jobs),
            ("batches", s.batches),
            ("batched_jobs", s.batched_jobs),
            ("speculative_launches", s.speculative_launches),
            ("speculative_wins", s.speculative_wins),
            ("requeued_shards", s.requeued_shards),
            ("requeued_jobs", s.requeued_jobs),
            ("worker_deaths", s.worker_deaths),
            ("chaos_delays", s.chaos_delays),
            ("chaos_pool_shrinks", s.chaos_pool_shrinks),
        ];
        let gauges: [(&str, u64); 4] = [
            ("queue_depth", s.queue_depth),
            ("queue_depth_max", s.queue_depth_max),
            ("history_patterns", s.history_patterns),
            ("shard_workers", s.shard_workers),
        ];
        let mut out = String::new();
        for (name, v) in counters {
            out.push_str(&format!(
                "# TYPE opsparse_{name}_total counter\nopsparse_{name}_total {v}\n"
            ));
        }
        for (name, v) in gauges {
            out.push_str(&format!("# TYPE opsparse_{name} gauge\nopsparse_{name} {v}\n"));
        }
        for (name, q) in [
            ("job_latency_p50_ns", s.p50_ns),
            ("job_latency_p99_ns", s.p99_ns),
            ("serve_latency_p50_ns", s.serve_p50_ns),
            ("serve_latency_p99_ns", s.serve_p99_ns),
        ] {
            if let Some(v) = q {
                out.push_str(&format!("# TYPE opsparse_{name} gauge\nopsparse_{name} {v}\n"));
            }
        }
        out.push_str("# TYPE opsparse_phase_latency_ns histogram\n");
        for (phase, h) in self.phases.iter() {
            h.render_prometheus(&mut out, "opsparse_phase_latency_ns", phase);
        }
        out
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub hash_routed: u64,
    pub block_routed: u64,
    pub sharded_routed: u64,
    /// Jobs on the block-row-sharded block-engine route.
    pub sharded_block_routed: u64,
    /// Block-routed jobs downgraded to hash for lack of a block engine.
    pub block_fallbacks: u64,
    /// Shard sub-jobs executed across the pool.
    pub shard_subjobs: u64,
    /// Distinct workers that executed shard sub-jobs.
    pub shard_workers: u64,
    pub nprod_total: u64,
    pub sym_cache_hits: u64,
    pub sym_cache_misses: u64,
    /// Shard sub-jobs replayed via shard-aware pattern-cache keys.
    pub shard_sym_cache_hits: u64,
    pub shard_sym_cache_misses: u64,
    /// Sharded jobs planned from measured history (warm-pattern
    /// consults; the re-cut applies only when it improves the model).
    pub replans: u64,
    /// Sharded jobs planned by the proxy (no history yet).
    pub replan_cold_misses: u64,
    /// Measured executions folded into the live ns-per-product fit.
    pub refit_updates: u64,
    /// Execution-history occupancy (patterns held / evicted so far).
    pub history_patterns: u64,
    pub history_evictions: u64,
    pub pool_device_mallocs: u64,
    pub pool_device_bytes: u64,
    pub pool_hits: u64,
    pub pool_reused_bytes: u64,
    /// Serving front door: coalesced attach count, admission rejects,
    /// batch flushes / members, and the queue-depth gauge + high-water.
    pub coalesce_hits: u64,
    pub rejected_jobs: u64,
    pub batches: u64,
    pub batched_jobs: u64,
    pub queue_depth: u64,
    pub queue_depth_max: u64,
    /// Failure domains: straggler speculation (backups launched / backups
    /// that reported first), dead-worker recovery (sub-jobs and whole
    /// jobs requeued, deaths survived), and the chaos injection that
    /// exercised them (delays applied, pools torn down). All zero when
    /// `--speculate off --chaos off`.
    pub speculative_launches: u64,
    pub speculative_wins: u64,
    pub requeued_shards: u64,
    pub requeued_jobs: u64,
    pub worker_deaths: u64,
    pub chaos_delays: u64,
    pub chaos_pool_shrinks: u64,
    pub p50_ns: Option<u64>,
    pub p99_ns: Option<u64>,
    /// Front-door (admission → fan-out) latency percentiles, per waiter.
    pub serve_p50_ns: Option<u64>,
    pub serve_p99_ns: Option<u64>,
}

impl MetricsSnapshot {
    /// Fraction of jobs that skipped their symbolic phase.
    pub fn sym_cache_hit_rate(&self) -> f64 {
        let total = self.sym_cache_hits + self.sym_cache_misses;
        if total == 0 {
            return 0.0;
        }
        self.sym_cache_hits as f64 / total as f64
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "jobs: submitted={} completed={} failed={}",
            self.jobs_submitted, self.jobs_completed, self.jobs_failed
        )?;
        writeln!(
            f,
            "routes: hash={} block={} sharded={} sharded_block={} \
             (sub-jobs={} over {} workers; block_fallbacks={})",
            self.hash_routed,
            self.block_routed,
            self.sharded_routed,
            self.sharded_block_routed,
            self.shard_subjobs,
            self.shard_workers,
            self.block_fallbacks
        )?;
        writeln!(f, "nprod total: {}", self.nprod_total)?;
        writeln!(
            f,
            "symbolic cache: hits={} misses={} ({:.0}% skipped); shard-level hits={} misses={}",
            self.sym_cache_hits,
            self.sym_cache_misses,
            100.0 * self.sym_cache_hit_rate(),
            self.shard_sym_cache_hits,
            self.shard_sym_cache_misses
        )?;
        writeln!(
            f,
            "adaptive: replans={} cold_misses={} refit_updates={} history={} patterns ({} evicted)",
            self.replans,
            self.replan_cold_misses,
            self.refit_updates,
            self.history_patterns,
            self.history_evictions
        )?;
        writeln!(
            f,
            "device pool: mallocs={} footprint={} reuse_hits={} reused={}",
            self.pool_device_mallocs,
            crate::util::fmt::bytes(self.pool_device_bytes as usize),
            self.pool_hits,
            crate::util::fmt::bytes(self.pool_reused_bytes as usize)
        )?;
        writeln!(
            f,
            "serve: coalesce_hits={} rejected={} batches={} batched_jobs={} queue_depth={} (max {})",
            self.coalesce_hits,
            self.rejected_jobs,
            self.batches,
            self.batched_jobs,
            self.queue_depth,
            self.queue_depth_max
        )?;
        writeln!(
            f,
            "failure domains: deaths={} requeued_shards={} requeued_jobs={} \
             spec_launches={} spec_wins={} chaos_delays={} pool_shrinks={}",
            self.worker_deaths,
            self.requeued_shards,
            self.requeued_jobs,
            self.speculative_launches,
            self.speculative_wins,
            self.chaos_delays,
            self.chaos_pool_shrinks
        )?;
        match (self.p50_ns, self.p99_ns) {
            (Some(p50), Some(p99)) => writeln!(
                f,
                "latency: p50={} p99={}",
                crate::util::fmt::ns(p50 as f64),
                crate::util::fmt::ns(p99 as f64)
            ),
            _ => writeln!(f, "latency: no samples"),
        }?;
        match (self.serve_p50_ns, self.serve_p99_ns) {
            (Some(p50), Some(p99)) => writeln!(
                f,
                "serve latency: p50={} p99={}",
                crate::util::fmt::ns(p50 as f64),
                crate::util::fmt::ns(p99 as f64)
            ),
            _ => writeln!(f, "serve latency: no samples"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        for ns in [100u64, 200, 300, 400, 1000] {
            m.observe_latency(ns);
        }
        let snap = m.snapshot();
        assert_eq!(snap.jobs_submitted, 3);
        assert_eq!(snap.p50_ns, Some(300));
        assert_eq!(snap.p99_ns, Some(1000));
    }

    #[test]
    fn empty_latency_is_none() {
        let m = Metrics::new();
        assert!(m.latency_percentile(0.5).is_none());
    }

    #[test]
    fn pool_observation_accumulates() {
        let m = Metrics::new();
        let d = crate::gpusim::PoolStats {
            requests: 4,
            pool_hits: 3,
            device_mallocs: 1,
            device_bytes: 4096,
            reused_bytes: 12_288,
            high_water_bytes: 16_384,
        };
        m.observe_pool(&d);
        m.observe_pool(&d);
        let snap = m.snapshot();
        assert_eq!(snap.pool_device_mallocs, 2);
        assert_eq!(snap.pool_device_bytes, 8192);
        assert_eq!(snap.pool_hits, 6);
        assert_eq!(snap.pool_reused_bytes, 24_576);
    }

    #[test]
    fn shard_subjob_telemetry_counts_distinct_workers() {
        let m = Metrics::new();
        m.observe_shard_subjob(0);
        m.observe_shard_subjob(2);
        m.observe_shard_subjob(0);
        let snap = m.snapshot();
        assert_eq!(snap.shard_subjobs, 3);
        assert_eq!(snap.shard_workers, 2, "worker 0 counted once");
    }

    #[test]
    fn queue_depth_gauge_tracks_high_water() {
        let m = Metrics::new();
        m.observe_queue_depth(3);
        m.observe_queue_depth(7);
        m.observe_queue_depth(2);
        let snap = m.snapshot();
        assert_eq!(snap.queue_depth, 2, "gauge holds the latest value");
        assert_eq!(snap.queue_depth_max, 7, "high-water mark sticks");
    }

    #[test]
    fn serve_latency_reservoir_is_separate_from_job_latency() {
        let m = Metrics::new();
        for ns in [10u64, 20, 30] {
            m.observe_serve_latency(ns);
        }
        let snap = m.snapshot();
        assert_eq!(snap.serve_p50_ns, Some(20));
        assert_eq!(snap.p50_ns, None, "job reservoir untouched");
    }

    #[test]
    fn cache_hit_rate() {
        let m = Metrics::new();
        m.sym_cache_hits.fetch_add(3, Ordering::Relaxed);
        m.sym_cache_misses.fetch_add(1, Ordering::Relaxed);
        assert!((m.snapshot().sym_cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_sum_and_count() {
        let h = Histogram::default();
        h.observe(500); // below the first bound
        h.observe(1_000); // exactly on a bound lands in that bucket
        h.observe(3_000_000);
        h.observe(u64::MAX / 2); // beyond every bound: +Inf bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_ns(), 500 + 1_000 + 3_000_000 + u64::MAX / 2);
        let mut out = String::new();
        h.render_prometheus(&mut out, "x_ns", "t");
        assert!(out.contains("x_ns_bucket{phase=\"t\",le=\"1000\"} 2\n"), "{out}");
        assert!(out.contains("x_ns_bucket{phase=\"t\",le=\"+Inf\"} 4\n"));
        assert!(out.contains("x_ns_count{phase=\"t\"} 4\n"));
    }

    /// Extract the text between `start` and the next line that is just
    /// `}` — enough to isolate a struct body or impl block in this file.
    fn section<'a>(src: &'a str, start: &str) -> &'a str {
        let s = src.find(start).unwrap_or_else(|| panic!("{start:?} not found in metrics.rs"));
        let rest = &src[s + start.len()..];
        let e = rest.find("\n}\n").unwrap_or(rest.len());
        &rest[..e]
    }

    /// The metrics/snapshot drift guard: every counter registered on
    /// `Metrics` must appear in `MetricsSnapshot`, be rendered by its
    /// `Display` impl, and be exposed by `to_prometheus` — a new
    /// counter silently missing from any of the three fails here.
    #[test]
    fn every_metrics_counter_reaches_snapshot_display_and_prometheus() {
        let src = include_str!("metrics.rs");
        let metrics_body = section(src, "pub struct Metrics {");
        let snapshot_body = section(src, "pub struct MetricsSnapshot {");
        let display_body = section(src, "impl std::fmt::Display for MetricsSnapshot {");
        let prom = Metrics::new().to_prometheus();
        let counters: Vec<&str> = metrics_body
            .lines()
            .filter_map(|l| {
                let l = l.trim();
                l.strip_prefix("pub ")
                    .and_then(|l| l.strip_suffix(": AtomicU64,"))
                    .filter(|name| name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'))
            })
            .collect();
        assert!(counters.len() >= 30, "counter extraction broke: {counters:?}");
        for name in counters {
            assert!(
                snapshot_body.contains(&format!("pub {name}: u64")),
                "counter {name} is registered in Metrics but missing from MetricsSnapshot"
            );
            assert!(
                display_body.contains(&format!("self.{name}")),
                "counter {name} is in the snapshot but not rendered by its Display impl"
            );
            assert!(
                prom.contains(&format!("opsparse_{name}")),
                "counter {name} is missing from the Prometheus exposition"
            );
        }
    }

    /// `to_prometheus` output is valid Prometheus text format: every
    /// line is a `# TYPE`/`# HELP` comment or `name[{labels}] value`,
    /// every sample's family has a TYPE line, and each histogram's
    /// `+Inf` bucket equals its `_count`.
    #[test]
    fn prometheus_exposition_is_well_formed() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(2, Ordering::Relaxed);
        m.observe_latency(1_500);
        m.observe_serve_latency(2_500_000);
        m.phases.queue_wait.observe(42);
        let text = m.to_prometheus();
        let mut typed: std::collections::HashSet<&str> = std::collections::HashSet::new();
        for line in text.lines().filter(|l| !l.is_empty()) {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let fam = it.next().expect("TYPE line names a family");
                let kind = it.next().expect("TYPE line has a kind");
                assert!(["counter", "gauge", "histogram"].contains(&kind), "{line}");
                typed.insert(fam);
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment shape: {line}");
            let (name_part, value) = line.rsplit_once(' ').expect("sample has a value");
            let name = name_part.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "bad metric name in {line}"
            );
            assert!(value.parse::<f64>().is_ok(), "non-numeric value in {line}");
            let family_known = typed.contains(name)
                || ["_bucket", "_sum", "_count"].iter().any(|suf| {
                    name.strip_suffix(suf).is_some_and(|fam| typed.contains(fam))
                });
            assert!(family_known, "sample {name} has no TYPE line");
        }
        assert!(text.contains("opsparse_jobs_submitted_total 2"));
        assert!(text.contains("# TYPE opsparse_phase_latency_ns histogram"));
        for phase in ["admit", "queue_wait", "batch_residency", "route_decision", "exec",
            "shard_exec", "stitch", "serve_total"]
        {
            assert!(
                text.contains(&format!("phase=\"{phase}\"")),
                "per-phase histogram {phase} missing from exposition"
            );
            let count_line = text
                .lines()
                .find(|l| l.starts_with(&format!(
                    "opsparse_phase_latency_ns_count{{phase=\"{phase}\"}}"
                )))
                .unwrap();
            let count: u64 = count_line.rsplit_once(' ').unwrap().1.parse().unwrap();
            let inf_line = text
                .lines()
                .find(|l| l.starts_with(&format!(
                    "opsparse_phase_latency_ns_bucket{{phase=\"{phase}\",le=\"+Inf\"}}"
                )))
                .unwrap();
            let inf: u64 = inf_line.rsplit_once(' ').unwrap().1.parse().unwrap();
            assert_eq!(inf, count, "+Inf bucket must equal _count for {phase}");
        }
        // the coarse phases fill from the existing observation points
        assert!(text.contains("opsparse_phase_latency_ns_count{phase=\"exec\"} 1"));
        assert!(text.contains("opsparse_phase_latency_ns_count{phase=\"serve_total\"} 1"));
        assert!(text.contains("opsparse_phase_latency_ns_count{phase=\"queue_wait\"} 1"));
    }
}
