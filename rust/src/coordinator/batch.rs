//! Size/age-watermarked batching for the serving front door.
//!
//! Many serving workloads are storms of *small* multiplies — each one
//! cheap enough that per-job queue traffic, worker wakeups, and cold
//! pool growth dominate its cost (the serving-scale echo of the §5.4
//! launch-overhead argument). The front door therefore accumulates
//! hash-routed requests in an open batch and flushes them to the
//! coordinator as **one worker visit**
//! ([`crate::coordinator::Coordinator::submit_batch`]): the members run
//! back-to-back on one worker's device pool and pattern cache, so the
//! visit is amortized and repeated patterns within the batch warm the
//! same cache — while results stay bit-identical to one-at-a-time
//! submission.
//!
//! A batch closes on whichever watermark trips first:
//!
//! * **size** — `max_jobs` members buys no further amortization per
//!   member, flush;
//! * **age** — the oldest member has waited `max_age`; latency bounds
//!   beat a fuller batch (the dispatcher polls [`Batcher::take_aged`]
//!   every tick).
//!
//! [`BatchConfig::default`] is **off**: the front door then forwards
//! every request individually, reproducing the pre-batching (PR 5)
//! submission pattern exactly.

use super::service::Job;
use std::time::{Duration, Instant};

/// Knobs of the front door's batcher. `enabled: false` (the default) is
/// the baseline: no batch is ever opened and every job is forwarded
/// individually.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchConfig {
    /// Accumulate hash-routed requests into batched worker visits.
    pub enabled: bool,
    /// Size watermark: flush when the open batch reaches this many jobs.
    pub max_jobs: usize,
    /// Age watermark: flush when the oldest member has waited this long.
    pub max_age: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { enabled: false, max_jobs: 8, max_age: Duration::from_millis(2) }
    }
}

impl BatchConfig {
    /// Batching on, with the default watermarks.
    pub fn on() -> BatchConfig {
        BatchConfig { enabled: true, ..BatchConfig::default() }
    }
}

/// The open-batch accumulator. Watermark policy only — it never talks
/// to the coordinator itself; the dispatcher submits whatever a method
/// returns. (It also doesn't check `BatchConfig::enabled`: the caller
/// decides whether to route jobs through the batcher at all.)
#[derive(Debug)]
pub struct Batcher {
    cfg: BatchConfig,
    open: Vec<Job>,
    /// When the current batch's first member arrived (age watermark).
    opened_at: Option<Instant>,
}

impl Batcher {
    pub fn new(cfg: BatchConfig) -> Self {
        Batcher { cfg, open: Vec::new(), opened_at: None }
    }

    /// Add one job to the open batch. Returns the batch when `job` trips
    /// the size watermark, `None` while it is still filling.
    pub fn push(&mut self, job: Job) -> Option<Vec<Job>> {
        if self.open.is_empty() {
            self.opened_at = Some(Instant::now());
        }
        self.open.push(job);
        if self.open.len() >= self.cfg.max_jobs.max(1) {
            return self.take();
        }
        None
    }

    /// The open batch, if its oldest member has waited past the age
    /// watermark. Poll once per dispatcher tick.
    pub fn take_aged(&mut self) -> Option<Vec<Job>> {
        match self.opened_at {
            Some(t) if t.elapsed() >= self.cfg.max_age => self.take(),
            _ => None,
        }
    }

    /// The open batch regardless of watermarks (shutdown drain).
    pub fn take(&mut self) -> Option<Vec<Job>> {
        if self.open.is_empty() {
            return None;
        }
        self.opened_at = None;
        Some(std::mem::take(&mut self.open))
    }

    /// Members currently waiting in the open batch.
    pub fn len(&self) -> usize {
        self.open.len()
    }

    pub fn is_empty(&self) -> bool {
        self.open.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn job(id: u64) -> Job {
        Job { id, a: Csr::identity(4), b: Csr::identity(4), force_route: None }
    }

    #[test]
    fn size_watermark_closes_the_batch() {
        let mut b = Batcher::new(BatchConfig {
            enabled: true,
            max_jobs: 3,
            max_age: Duration::from_secs(3600),
        });
        assert!(b.push(job(0)).is_none());
        assert!(b.push(job(1)).is_none());
        let batch = b.push(job(2)).expect("third member trips the size watermark");
        assert_eq!(batch.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(b.is_empty(), "flushing resets the accumulator");
        // the next batch starts fresh
        assert!(b.push(job(3)).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn age_watermark_closes_a_partial_batch() {
        let mut b = Batcher::new(BatchConfig {
            enabled: true,
            max_jobs: 100,
            max_age: Duration::from_millis(0),
        });
        assert!(b.take_aged().is_none(), "no open batch, nothing to age out");
        assert!(b.push(job(0)).is_none());
        assert!(b.push(job(1)).is_none());
        // max_age 0: the open batch is immediately aged
        let batch = b.take_aged().expect("aged batch flushes");
        assert_eq!(batch.len(), 2);
        assert!(b.take_aged().is_none());
        // a long age keeps the batch open
        let mut slow = Batcher::new(BatchConfig {
            enabled: true,
            max_jobs: 100,
            max_age: Duration::from_secs(3600),
        });
        slow.push(job(0));
        assert!(slow.take_aged().is_none(), "an hour has not passed");
        assert_eq!(slow.take().expect("explicit drain").len(), 1);
    }

    #[test]
    fn degenerate_size_watermark_flushes_every_push() {
        // max_jobs 0 clamps to 1: every push returns a singleton batch
        let mut b = Batcher::new(BatchConfig {
            enabled: true,
            max_jobs: 0,
            max_age: Duration::from_secs(3600),
        });
        let batch = b.push(job(7)).expect("singleton flush");
        assert_eq!(batch.len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn default_is_off() {
        let d = BatchConfig::default();
        assert!(!d.enabled, "batching must default to the PR 5 baseline");
        assert!(BatchConfig::on().enabled);
        assert_eq!(BatchConfig::on().max_jobs, d.max_jobs);
    }
}
